"""Encoding layer (dict / RLE / delta-bitpack between varcodec and colfile):

* encode -> decode identity for every encoding x column kind combination,
  with batch ``read_range``/``read_many`` values AND ``ReadCounters``
  bit-identical to a scalar ``value_at`` loop (the Table-1 accounting
  contract extended to every encoding);
* automatic per-block selection from write-time stats, plus the forced
  ``ColumnFormat(encoding=...)`` knob that makes each path deterministic;
* ``DictRaggedColumn`` predicate pushdown on codes (contains/eq evaluate on
  the dictionary, broadcast through codes, survive slicing/concat);
* dict-encoded token pages feeding the Pallas device-decode path with no
  private dictionary sidecars;
* backward compatibility: version-1 files written by the pre-encoding
  writer (checked-in fixtures) still read bit-for-bit.
"""
import json
import os

import numpy as np
import pytest

from repro.core import (
    ARRAY, BYTES, DictRaggedColumn, INT32, INT64, MAP, RaggedColumn, STRING,
    CIFReader, COFWriter, storage_report, urlinfo_schema,
)
from repro.core.colfile import (
    ColumnFileReader, ColumnFileWriter, ColumnFormat, SKIPLIST_DICT_BLOCK,
)
from repro.core.encodings import ENCODINGS, candidates, encode_block, plain_size

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")

N = 2600  # spans multiple encoded blocks, skip groups, and cblocks


def _values_for(encoding, typ, rnd, n=N):
    """Data distributions that make ``encoding`` the natural choice."""
    k = typ.kind
    if encoding == "dict":
        if k == "string":
            return [rnd.choice(["text/html", "app/pdf", "img/png", "text/xml"])
                    for _ in range(n)]
        if k == "bytes":
            return [rnd.choice([b"alpha", b"beta", b"gamma-long-payload"])
                    for _ in range(n)]
        if k == "array":
            return [[rnd.randint(0, 400) for _ in range(16)] for _ in range(n)]
        return [rnd.choice([3, 77, 1024, -5]) for _ in range(n)]
    if encoding == "rle":
        if k == "string":
            vals = []
            while len(vals) < n:
                vals.extend([f"run{rnd.randint(0, 5)}"] * rnd.randint(1, 40))
            return vals[:n]
        base = [rnd.randint(0, 9) for _ in range(n // 20 + 1)]
        return [v for v in base for _ in range(20)][:n]
    if encoding == "delta":
        out, cur = [], rnd.randint(0, 1000)
        for _ in range(n):
            cur += rnd.randint(0, 30)
            out.append(cur)
        return out
    # plain: high-entropy data no lightweight encoding should beat
    if k == "string":
        return ["x" * rnd.randint(0, 60) + str(rnd.random()) for _ in range(n)]
    if k == "bytes":
        return [bytes([rnd.randrange(256) for _ in range(rnd.randint(0, 40))])
                for _ in range(n)]
    if k == "map":
        return [{f"k{rnd.randint(0, 9)}": rnd.randint(-99, 99)
                 for _ in range(rnd.randint(0, 5))} for _ in range(n)]
    return [rnd.randint(-(2**40), 2**40) for _ in range(n)]


def _build(typ, fmt, vals):
    w = ColumnFileWriter(typ, fmt)
    for v in vals:
        w.append(v)
    return w.finish(), w


def _as_list(v):
    return v.tolist() if hasattr(v, "tolist") else v


# every encoding x kind combination each path can express.  skiplist keeps
# cells individually skippable, so only plain/dict apply; dcsl IS a dict
# encoding already and stays plain.
COMBOS = [
    ("plain", "plain", INT64()), ("plain", "dict", INT64()),
    ("plain", "rle", INT64()), ("plain", "delta", INT64()),
    ("plain", "plain", STRING()), ("plain", "dict", STRING()),
    ("plain", "rle", STRING()), ("plain", "dict", BYTES()),
    ("plain", "dict", ARRAY(INT32())),
    ("cblock", "plain", INT64()), ("cblock", "dict", INT64()),
    ("cblock", "rle", INT64()), ("cblock", "delta", INT64()),
    ("cblock", "dict", STRING()), ("cblock", "rle", STRING()),
    ("skiplist", "plain", STRING()), ("skiplist", "dict", STRING()),
    ("skiplist", "dict", INT64()), ("skiplist", "dict", BYTES()),
    ("dcsl", "plain", MAP(STRING())),
]


@pytest.mark.parametrize(
    "kind,encoding,typ", COMBOS,
    ids=[f"{k}-{e}-{t.kind}" for k, e, t in COMBOS],
)
def test_forced_encoding_batch_matches_scalar(kind, encoding, typ, rnd):
    """The forced-encoding knob makes every path reachable deterministically;
    on each, batch reads return the same values AND the same counters as a
    scalar ``value_at`` loop (gappy ``read_many`` included)."""
    if kind == "dcsl":
        vals = [{f"key{rnd.randint(0, 9)}": f"v{rnd.randint(0, 50)}"
                 for _ in range(4)} for _ in range(N)]
    else:
        vals = _values_for(encoding, typ, rnd)
    fmt = ColumnFormat(kind, codec="zlib" if kind == "cblock" else "none",
                       encoding=encoding)
    raw, w = _build(typ, fmt, vals)
    if kind in ("plain", "cblock"):
        assert set(w.encoding_stats()["blocks"]) == {encoding}
    elif kind == "skiplist":
        assert ColumnFileReader(raw, typ).encoding == encoding
    scalar, batch = ColumnFileReader(raw, typ), ColumnFileReader(raw, typ)
    expect = [scalar.value_at(i) for i in range(len(vals))]
    got = _as_list(batch.read_range(0, len(vals)))
    assert got == expect == list(vals)
    assert vars(batch.counters) == vars(scalar.counters)
    # gappy monotone access
    idx = sorted(rnd.sample(range(len(vals)), 211))
    s2, b2 = ColumnFileReader(raw, typ), ColumnFileReader(raw, typ)
    assert _as_list(b2.read_many(idx)) == [s2.value_at(i) for i in idx]
    assert vars(b2.counters) == vars(s2.counters)


def test_auto_selection_from_write_stats(rnd):
    """Per-block stats pick the right encoding without user input."""
    cases = [
        (INT64(), _values_for("delta", INT64(), rnd), "delta"),
        (INT64(), _values_for("dict", INT64(), rnd), "dict"),
        (INT64(), _values_for("plain", INT64(), rnd), "plain"),
        (STRING(), _values_for("dict", STRING(), rnd), "dict"),
        (STRING(), _values_for("rle", STRING(), rnd), "rle"),
        (STRING(), _values_for("plain", STRING(), rnd), "plain"),
    ]
    for typ, vals, expect in cases:
        raw, w = _build(typ, ColumnFormat("plain"), vals)
        blocks = w.encoding_stats()["blocks"]
        assert set(blocks) == {expect}, (typ.kind, expect, blocks)
        # and the chosen payload really is smaller than plain (or is plain)
        st = w.encoding_stats()
        if expect != "plain":
            assert st["encoded_bytes"] < st["raw_bytes"]
        assert _as_list(ColumnFileReader(raw, typ).read_range(0, len(vals))) == vals


def test_auto_selection_varies_per_block(rnd):
    """A column whose blocks differ picks encodings PER BLOCK."""
    sorted_block = _values_for("delta", INT64(), rnd, 2048)
    random_block = _values_for("plain", INT64(), rnd, 2048)
    vals = sorted_block + random_block
    raw, w = _build(INT64(), ColumnFormat("plain"), vals)
    assert w.encoding_stats()["blocks"] == {"delta": 1, "plain": 1}
    assert _as_list(ColumnFileReader(INT64(), raw) if False else
                    ColumnFileReader(raw, INT64()).read_range(0, len(vals))) == vals


def test_encode_block_margin():
    """Selection needs a real win: a marginal dict candidate loses to plain."""
    # two distinct long strings, each once: dict == plain payload + overhead
    name, payload, raw = encode_block(STRING(), ["a" * 50, "b" * 50])
    assert name == "plain"


def test_invalid_forced_encodings_rejected():
    with pytest.raises(AssertionError):
        ColumnFileWriter(STRING(), ColumnFormat("plain", encoding="delta"))
    with pytest.raises(AssertionError):
        ColumnFileWriter(STRING(), ColumnFormat("skiplist", encoding="rle"))
    with pytest.raises(AssertionError):
        ColumnFileWriter(MAP(STRING()), ColumnFormat("dcsl", encoding="dict"))
    with pytest.raises(AssertionError):
        ColumnFileWriter(MAP(STRING()), ColumnFormat("skiplist", encoding="dict"))


def test_skiplist_dict_keeps_skipping_cheap(rnd):
    """Dict-mode skip lists still jump: sparse access touches a small
    fraction of what a dense scan touches (the §5.2 property survives the
    encoding layer)."""
    vals = [rnd.choice(["en", "jp", "de", "fr"]) for _ in range(5000)]
    raw, _ = _build(STRING(), ColumnFormat("skiplist"), vals)
    r = ColumnFileReader(raw, STRING())
    assert r.encoding == "dict"  # auto resolved: low cardinality
    for i in range(0, 5000, 1000):
        assert r.value_at(i) == vals[i]
    sparse_touched = r.counters.bytes_touched
    r2 = ColumnFileReader(raw, STRING())
    assert _as_list(r2.read_range(0, 5000)) == vals
    assert sparse_touched < r2.counters.bytes_touched / 5


def test_dict_ragged_column_pushdown(rnd):
    """contains/eq evaluate once per DICTIONARY entry and broadcast through
    codes; views preserve the codes."""
    vals = [rnd.choice(["text/html", "app/pdf", "img/png"]) for _ in range(1500)]
    raw, _ = _build(
        STRING(), ColumnFormat("plain", encoding="dict", enc_block=2048), vals
    )
    col = ColumnFileReader(raw, STRING()).read_range(0, len(vals))
    assert isinstance(col, DictRaggedColumn)
    assert len(col.dict_starts) == 3  # one offset per DISTINCT value
    np.testing.assert_array_equal(
        col.contains("pdf"), np.array(["pdf" in v for v in vals]))
    np.testing.assert_array_equal(
        col.eq("img/png"), np.array([v == "img/png" for v in vals]))
    view = col[100:700]
    assert isinstance(view, DictRaggedColumn) and view == vals[100:700]
    np.testing.assert_array_equal(
        view.eq("text/html"), np.array([v == "text/html" for v in vals[100:700]]))
    picked = col[np.array([5, 5, 1400])]
    assert isinstance(picked, DictRaggedColumn)
    assert picked == [vals[5], vals[5], vals[1400]]
    assert col.tolist() == vals


def test_plain_ragged_eq(rnd):
    vals = ["x" * rnd.randint(0, 20) + str(i % 7) for i in range(400)]
    raw, _ = _build(STRING(), ColumnFormat("plain", encoding="plain"), vals)
    col = ColumnFileReader(raw, STRING()).read_range(0, len(vals))
    assert isinstance(col, RaggedColumn)
    np.testing.assert_array_equal(
        col.eq(vals[13]), np.array([v == vals[13] for v in vals]))


def test_block_skipping_never_decodes_untouched_blocks(rnd):
    """The encoded-block plain kind gains cblock-style block skipping: a
    sparse read leaves far-away blocks untouched (bytes_touched ~ headers +
    the two visited blocks only)."""
    vals = _values_for("plain", STRING(), rnd, 8192)
    raw, _ = _build(STRING(), ColumnFormat("plain"), vals)
    r = ColumnFileReader(raw, STRING())
    r.read_many([5, 8000])  # first and last block only
    dense = ColumnFileReader(raw, STRING())
    dense.read_range(0, len(vals))
    assert r.counters.bytes_touched < dense.counters.bytes_touched / 1.8
    assert r.counters.blocks_skipped >= 2


def test_meta_json_records_encoding_stats(tmp_path, rnd):
    root = str(tmp_path / "d")
    schema = urlinfo_schema()
    from repro.launch.load_data import synth_crawl_records

    w = COFWriter(root, schema, split_records=256)
    w.append_all(synth_crawl_records(512))
    w.close()
    with open(os.path.join(root, "split-00000", "_meta.json")) as f:
        meta = json.load(f)
    assert "encodings" in meta
    ft = meta["encodings"]["fetchTime"]
    assert ft["blocks"] == {"delta": 1}  # fetchTime is monotone in the synth
    assert 0 < ft["encoded_bytes"] < ft["raw_bytes"]
    rep = storage_report(root)
    assert rep["fetchTime"]["blocks"] == {"delta": 2}  # both splits
    assert rep["fetchTime"]["ratio"] < 0.5
    # the report never opens a column file — only _meta.json
    assert set(rep) == set(schema.names())


def test_reads_pre_encoding_fixtures():
    """Version-1 files written by the pre-encoding-layer writer (checked-in
    fixtures) still read: scalar, batch, and gappy access."""
    with open(os.path.join(FIXTURES, "prepr_expected.json")) as f:
        exp = json.load(f)
    types = {
        "plain_int64": INT64(), "skiplist_string": STRING(),
        "cblock_zlib_string": STRING(), "dcsl_map": MAP(STRING()),
    }
    for name, typ in types.items():
        with open(os.path.join(FIXTURES, f"prepr_{name}.col"), "rb") as f:
            raw = f.read()
        r = ColumnFileReader(raw, typ)
        assert r.version == 1 and r.encoding == "legacy"
        assert _as_list(r.read_range(0, r.n)) == exp[name]
        r2 = ColumnFileReader(raw, typ)
        assert [r2.value_at(i) for i in range(0, r2.n, 13)] == exp[name][::13]
        if name == "dcsl_map":
            r3 = ColumnFileReader(raw, typ)
            assert r3.lookup_many([3, 700, 1200], "k5") == [
                exp[name][i].get("k5") for i in (3, 700, 1200)
            ]


def test_tokens_have_no_private_dictionary(tmp_path):
    """The token corpus rides the generic dict encoding: no sidecar files,
    dictionary read from the column's dict page, packed words identical to
    what unpack expects, device decode == np decode (interpret mode)."""
    from repro.data.tokens import TokenCorpus, TokenCorpusWriter
    from repro.launch.load_data import synth_token_docs

    root = str(tmp_path / "corpus")
    w = TokenCorpusWriter(root, seq_len=64, split_records=32)
    for toks, meta in synth_token_docs(60, vocab=500):
        w.add_document(toks, meta)
    w.close()
    corpus = TokenCorpus(root)
    sid = corpus.split_ids()[0]
    sdir = dict(corpus.splits)[sid]
    assert not os.path.exists(os.path.join(sdir, "tokens.dict.npy"))
    assert not os.path.exists(os.path.join(sdir, "tokens.meta.json"))
    sp = corpus.open_split(sid)
    page = sp.reader.readers["tokens"].dict_page()
    np.testing.assert_array_equal(sp.dictionary, np.asarray(page.values, np.int32))
    # the dictionary is the sorted unique token set of the split
    assert (np.diff(sp.dictionary) > 0).all()
    # generic batch read of the array column == decoded records
    sp2 = corpus.open_split(sid)
    toks, _ = sp2.record_batch(list(range(8)), decode="np")
    generic = corpus.open_split(sid).reader.readers["tokens"].read_range(0, 8)
    np.testing.assert_array_equal(toks, np.asarray(generic, np.int32))
    # device decode consumes the page words through the Pallas kernels
    sp_d = corpus.open_split(sid)
    td, md = sp_d.record_batch([1, 5, 9], decode="device")
    sp_n = corpus.open_split(sid)
    tn, mn = sp_n.record_batch([1, 5, 9], decode="np")
    np.testing.assert_array_equal(td, tn)
    np.testing.assert_array_equal(md, mn)


def test_legacy_token_corpus_still_reads(tmp_path, rnd):
    """Pre-encoding-layer corpora (BYTES token cells + tokens.dict.npy /
    tokens.meta.json sidecars, exactly what the old TokenCorpusWriter
    produced) still read through TokenSplit's legacy branch, all decode
    modes included."""
    from repro.data.tokens import (
        TokenCorpus, bits_for, legacy_token_schema, pack_bits, pack_codes,
    )

    root = str(tmp_path / "legacy")
    seq_len, n_seq = 32, 20
    seqs = [np.asarray([rnd.randint(0, 199) for _ in range(seq_len)], np.int32)
            for _ in range(n_seq)]
    dictionary = np.unique(np.concatenate(seqs))
    bits = bits_for(len(dictionary))
    code_of = {int(t): i for i, t in enumerate(dictionary)}
    # write the split exactly as the pre-PR writer did
    w = COFWriter(root, legacy_token_schema(),
                  formats={"meta": ColumnFormat("dcsl"),
                           # legacy tokens were RAW packed bytes, not v2
                           # dict pages: force plain to mimic the old cells
                           "tokens": ColumnFormat("plain", encoding="plain"),
                           "loss_mask": ColumnFormat("plain", encoding="plain")},
                  split_records=n_seq)
    for seq in seqs:
        codes = np.asarray([code_of[int(t)] for t in seq], np.uint32)
        w.append({"tokens": pack_codes(codes, bits), "n_tokens": seq_len,
                  "loss_mask": pack_bits(np.ones(seq_len, np.int32)),
                  "meta": {"doc": "legacy"}})
    w.close()
    sdir = os.path.join(root, "split-00000")
    np.save(os.path.join(sdir, "tokens.dict.npy"), dictionary.astype(np.int32))
    with open(os.path.join(sdir, "tokens.meta.json"), "w") as f:
        json.dump({"bits": bits, "seq_len": seq_len}, f)
    with open(os.path.join(root, "corpus.json"), "w") as f:
        json.dump({"seq_len": seq_len, "n_sequences": n_seq, "vocab_size": 200}, f)

    corpus = TokenCorpus(root)
    sp = corpus.open_split(0)
    assert sp.legacy
    ids = [0, 3, 4, 11]
    t_np, m = sp.record_batch(ids, decode="np")
    np.testing.assert_array_equal(t_np, np.stack([seqs[i] for i in ids]))
    assert m.shape == (len(ids), seq_len) and (m == 1).all()
    t_py, _ = corpus.open_split(0).record_batch(ids, decode="py")
    np.testing.assert_array_equal(t_py, t_np)
    t1, _ = corpus.open_split(0).record(2, decode="np")
    np.testing.assert_array_equal(t1, seqs[2])


def test_forced_delta_falls_back_per_block_when_inapplicable():
    """A forced delta encoding on a block whose deltas exceed 32 bits falls
    back to plain for THAT block instead of aborting the write."""
    vals = [100, 50, -3000, 7, 7, 10**12, 3, 2**61, -(2**60), 12]
    raw, w = _build(INT64(), ColumnFormat("plain", encoding="delta"), vals)
    assert w.encoding_stats()["blocks"] == {"plain": 1}
    assert _as_list(ColumnFileReader(raw, INT64()).read_range(0, len(vals))) == vals


def test_dcsl_lane_walk_matches_chain_walk(rnd):
    """The lockstep-lane in-group walker is bit-identical to the scalar
    chain walk — values, every counter, and the reader end state — at sizes
    above the lane threshold, including continuation calls."""
    from repro.core.schema import MAP

    typ = MAP(STRING())
    vals = [{f"k{rnd.randint(0, 15)}": f"v{rnd.randint(0, 99)}"
             for _ in range(rnd.randint(0, 6))} for _ in range(2600)]
    w = ColumnFileWriter(typ, ColumnFormat("dcsl"))
    for v in vals:
        w.append(v)
    raw = w.finish()
    idx1 = sorted(rnd.sample(range(1300), 600))
    idx2 = sorted(rnd.sample(range(max(idx1) + 1, 2600), 550))
    lanes, chain = ColumnFileReader(raw, typ), ColumnFileReader(raw, typ)
    assert lanes._dcsl._ensure_chain()
    out_l = lanes._dcsl._lookup_many_lanes(idx1, "k5") + \
        lanes._dcsl._lookup_many_lanes(idx2, "k5")
    assert chain._dcsl._ensure_chain()
    out_c = chain._dcsl._lookup_many_chain(idx1, "k5") + \
        chain._dcsl._lookup_many_chain(idx2, "k5")
    lanes._sync_dcsl_counters()
    chain._sync_dcsl_counters()
    assert out_l == out_c == [vals[i].get("k5") for i in idx1 + idx2]
    assert vars(lanes.counters) == vars(chain.counters)
    # and the public entry point picks the lane path at this size
    pub = ColumnFileReader(raw, typ)
    assert pub.lookup_many(idx1, "k5") == out_l[: len(idx1)]


def test_read_packed_counters_match_read_many(tmp_path):
    """The raw-page fast path reports exactly the work read_many would."""
    from repro.data.tokens import TokenCorpus, TokenCorpusWriter
    from repro.launch.load_data import synth_token_docs

    root = str(tmp_path / "corpus")
    w = TokenCorpusWriter(root, seq_len=32, split_records=64)
    for toks, meta in synth_token_docs(80, vocab=300):
        w.add_document(toks, meta)
    w.close()
    corpus = TokenCorpus(root)
    sp_a, sp_b = corpus.open_split(0), corpus.open_split(0)
    ids = [2, 3, 4, 17, 40]
    sp_a.reader.readers["tokens"].read_packed(ids)
    sp_b.reader.readers["tokens"].read_many(ids)
    assert vars(sp_a.reader.readers["tokens"].counters) == vars(
        sp_b.reader.readers["tokens"].counters
    )
    # mixing the two access styles on ONE reader neither crashes nor
    # recounts the page bytes, in either order
    rd_m = corpus.open_split(0).reader.readers["tokens"]
    rd_m.read_packed([0, 1])
    assert len(rd_m.read_range(2, 4)) == 2
    rd_n = corpus.open_split(0).reader.readers["tokens"]
    rd_n.value_at(0)
    rd_n.read_packed([2, 3])
    rd_ref = corpus.open_split(0).reader.readers["tokens"]
    rd_ref.read_many([0, 2, 3])
    assert vars(rd_n.counters) == vars(rd_ref.counters)


# -- property tests (hypothesis is an optional dep; only these skip without
# it — the deterministic tests above always run) ------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @given(st.lists(st.integers(-(2**62), 2**62), min_size=1, max_size=300))
    @settings(max_examples=60, deadline=None)
    def test_property_int_roundtrip_every_encoding(vals):
        for enc in ("plain", "dict", "rle", "delta"):
            payload = ENCODINGS[enc].encode(INT64(), vals)
            if payload is None:  # delta: deltas too wide to pack
                continue
            got = ENCODINGS[enc].decode_all(INT64(), payload, 0, len(payload), len(vals))
            assert _as_list(got) == vals, enc

    @given(st.lists(st.text(max_size=12), min_size=1, max_size=200))
    @settings(max_examples=60, deadline=None)
    def test_property_string_roundtrip_every_encoding(vals):
        for enc in ("plain", "dict", "rle"):
            payload = ENCODINGS[enc].encode(STRING(), vals)
            got = ENCODINGS[enc].decode_all(STRING(), payload, 0, len(payload), len(vals))
            assert _as_list(got) == vals, enc

    @given(st.lists(st.lists(st.integers(0, 5000), min_size=0, max_size=9),
                    min_size=1, max_size=80))
    @settings(max_examples=40, deadline=None)
    def test_property_array_dict_roundtrip(vals):
        payload = ENCODINGS["dict"].encode(ARRAY(INT32()), vals)
        got = ENCODINGS["dict"].decode_all(ARRAY(INT32()), payload, 0, len(payload), len(vals))
        assert got == vals

    @given(st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=300))
    @settings(max_examples=40, deadline=None)
    def test_property_plain_size_is_exact(vals):
        assert plain_size(INT64(), vals) == len(ENCODINGS["plain"].encode(INT64(), vals))

    @given(st.data())
    @settings(max_examples=40, deadline=None)
    def test_property_auto_never_loses_data(data):
        typ = data.draw(st.sampled_from([INT64(), STRING(), BYTES()]))
        if typ.kind == "int64":
            vals = data.draw(st.lists(st.integers(-(2**62), 2**62), min_size=1, max_size=200))
        elif typ.kind == "string":
            vals = data.draw(st.lists(st.text(max_size=10), min_size=1, max_size=200))
        else:
            vals = data.draw(st.lists(st.binary(max_size=12), min_size=1, max_size=200))
        name, payload, raw = encode_block(typ, vals)
        assert name in candidates(typ)
        got = ENCODINGS[name].decode_all(typ, payload, 0, len(payload), len(vals))
        assert _as_list(got) == vals
