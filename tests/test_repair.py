"""Self-healing corpus (PR 7): atomic durable commits, checksum scrubber,
replica repair, read-repair queue, and quarantine.

Tentpole invariants under test:

  * CRASH SAFETY — a writer killed at ANY byte offset of ANY write
    operation (column files, ``_meta.json``, the commit manifest, the
    publish rename) leaves the corpus readable at exactly the prior
    committed state, fsck-clean, and recoverable by re-running the writer.
  * SELF-HEALING — ``repair()`` re-replicates corrupt copies from clean
    replicas so a job that PR 6 alone fails with ``CoverageError``
    completes bit-identically to a no-fault run.
  * DETERMINISM — ``RepairReport`` and ``ScanStats.repair_queue`` are
    bit-identical across reruns and serial vs concurrent schedules.
"""
import json
import os
import shutil

import pytest

from repro.core import (
    CIFReader, COFWriter, ColumnFormat, ColumnType, CorruptFileError,
    CoverageError, FailurePolicy, FaultPlan, Placement, SplitRetryExhausted,
    SplitUnserveableError, add_column, format_storage_report, fsck,
    list_splits, quarantined_splits, repair, urlinfo_schema,
)
from repro.core import cof, durable
from repro.core.mapreduce import (
    fig1_map_batch, fig1_reduce, fig1_where, run_job,
)
from conftest import make_crawl_records

POLICY = FailurePolicy()
N_SPLITS, N_HOSTS, SPLIT_RECORDS = 6, 4, 50


def _as_list(vals):
    return vals.tolist() if hasattr(vals, "tolist") else list(vals)


def build_crawl(root, n=N_SPLITS * SPLIT_RECORDS, split_records=SPLIT_RECORDS):
    w = COFWriter(root, urlinfo_schema(),
                  formats={"metadata": ColumnFormat("dcsl"),
                           "url": ColumnFormat("skiplist")},
                  split_records=split_records)
    w.append_all(make_crawl_records(n))
    w.close()
    return root


def _run(root, plan=None, policy=None, n_workers=1, placement=None,
         split_ids=None):
    r = CIFReader(root, columns=["url", "metadata"],
                  fault_plan=plan, failure_policy=policy)
    want = [i for i, _ in r.splits()
            if split_ids is None or i in split_ids]
    # run_job requires len(split_ids) == placement.n_splits, so a corpus
    # with quarantined (or filtered) splits gets a placement sized to the
    # surviving split list
    p = placement if placement is not None else Placement(len(want), N_HOSTS)
    ids, ob = r.job_inputs(batch_size=64, where=fig1_where(), placement=p)
    ids = [i for i in ids if split_ids is None or i in split_ids]
    res = run_job(ids, reduce_fn=fig1_reduce, n_hosts=N_HOSTS, placement=p,
                  open_split_batches=ob, map_batch_fn=fig1_map_batch(),
                  n_workers=n_workers, fault_plan=plan, failure_policy=policy,
                  scan_stats=r.stats)
    return res, r.stats, p


def _pre_existing(stats):
    return {k: getattr(stats, k) for k in (
        "bytes_io", "bytes_touched", "bytes_decoded", "cells_decoded",
        "cells_skipped", "blocks_decompressed", "records_scanned",
        "files_opened", "blocks_pruned_stats", "rows_short_circuited")}


# -- crash injection: the commit protocol (tentpole layer 1) ------------------


class Crash(BaseException):
    """The writer process dies NOW.  BaseException so no recovery path in
    the code under test can accidentally swallow it."""


class CrashingIO:
    """Kill the writer at durable-write op number ``stop``, with ``frac``
    of that op's payload flushed.  Leaves exactly what a real mid-write
    kill leaves: a torn ``.tmp`` (never a torn published file) — or, for
    the publish rename, a fully-built but never-renamed building dir."""

    def __init__(self, mp, stop, frac):
        self.stop = stop
        self.frac = frac
        self.ops = 0
        self.renames = 0
        real_write = durable.durable_write
        real_replace = os.replace

        def dw(path, data, *, fsync=True):
            if self._fire():
                with open(path + ".tmp", "wb") as f:
                    f.write(data[: int(len(data) * frac)])
                raise Crash(path)
            real_write(path, data, fsync=fsync)

        def dwj(path, obj, *, fsync=True):
            dw(path, json.dumps(obj, sort_keys=True).encode("utf-8"),
               fsync=fsync)

        def replace(src, dst):
            if cof.is_building_dir(os.path.basename(src)):
                if self._fire():
                    raise Crash(src)
                real_replace(src, dst)
                self.renames += 1
            else:
                real_replace(src, dst)

        mp.setattr(cof, "durable_write", dw)
        mp.setattr(cof, "durable_write_json", dwj)
        mp.setattr(os, "replace", replace)

    def _fire(self):
        self.ops += 1
        return self.ops - 1 == self.stop


CRASH_SPLITS, CRASH_RECORDS = 3, 20


def _crash_write(root, stop, frac, records):
    with pytest.MonkeyPatch.context() as mp:
        io = CrashingIO(mp, stop, frac)
        try:
            w = COFWriter(root, urlinfo_schema(), split_records=CRASH_RECORDS)
            w.append_all(records)
            w.close()
        except Crash:
            pass
    return io


def test_writer_crash_at_every_offset_preserves_committed_state(tmp_path):
    """Exhaustive deterministic sweep: one corpus write per (op, fraction)
    crash point — mid-column-file, mid-``_meta.json``, mid-manifest
    (pre-marker), and at the publish rename.  After every crash the corpus
    reads back EXACTLY the committed prefix, fsck is clean, and re-running
    the writer recovers the full dataset."""
    records = make_crawl_records(CRASH_SPLITS * CRASH_RECORDS)
    urls = [r["url"] for r in records]

    # count the write ops of one clean run (also sanity: Crash never fires)
    probe = _crash_write(str(tmp_path / "probe"), stop=-1, frac=0.0,
                         records=records)
    total_ops, total_renames = probe.ops, probe.renames
    assert total_renames == CRASH_SPLITS

    for stop in range(total_ops):
        for frac in (0.0, 0.5, 1.0):
            root = str(tmp_path / f"c{stop}_{int(frac * 2)}")
            io = _crash_write(root, stop, frac, records)
            assert io.ops == stop + 1  # the sweep really hit this op
            committed = io.renames
            # visible corpus == the committed prefix, bit for bit
            got_splits = list_splits(root)
            assert [i for i, _ in got_splits] == list(range(committed))
            if os.path.exists(os.path.join(root, "schema.json")):
                r = CIFReader(root, columns=["url"])
                got = []
                for b in r.scan_batches(batch_size=64):
                    got.extend(_as_list(b["url"]))
                assert got == urls[: committed * CRASH_RECORDS]
            else:  # crashed writing schema.json itself: nothing visible
                assert committed == 0
            # never a parse error, never damage — just debris
            report = fsck(root)
            assert report.clean, report.format()
            assert not report.quarantined
            # recovery: re-running the writer heals every crash point
            w = COFWriter(root, urlinfo_schema(), split_records=CRASH_RECORDS)
            w.append_all(records)
            w.close()
            r = CIFReader(root, columns=["url"])
            got = []
            for b in r.scan_batches(batch_size=64):
                got.extend(_as_list(b["url"]))
            assert got == urls
            assert fsck(root).clean
            shutil.rmtree(root)  # keep the sweep's disk footprint flat


def test_add_column_crash_at_every_op_preserves_readable_state(tmp_path):
    """Schema evolution is crash-safe too: ``add_column`` publishes
    schema.json LAST, so a crash at any earlier durable write leaves the
    new column invisible and every split readable at its prior state."""
    root = str(tmp_path / "d")
    records = make_crawl_records(CRASH_SPLITS * CRASH_RECORDS)
    urls = [r["url"] for r in records]
    w = COFWriter(root, urlinfo_schema(), split_records=CRASH_RECORDS)
    w.append_all(records)
    w.close()

    def values_fn(si, n):
        return range(si * 1000, si * 1000 + n)

    def try_add(stop, frac):
        with pytest.MonkeyPatch.context() as mp:
            io = CrashingIO(mp, stop, frac)
            try:
                add_column(root, "rank", ColumnType("int64"), values_fn)
            except Crash:
                return io, False
        return io, True

    probe, done = try_add(stop=-1, frac=0.0)
    assert done
    # reset to the pre-evolution corpus for the sweep
    shutil.rmtree(root)
    w = COFWriter(root, urlinfo_schema(), split_records=CRASH_RECORDS)
    w.append_all(records)
    w.close()

    for stop in range(probe.ops):
        io, done = try_add(stop, 0.5)
        assert not done and io.ops == stop + 1
        # schema.json is the last op, so every crash leaves "rank" invisible
        r = CIFReader(root, columns=["url"])
        assert "rank" not in r.schema
        got = []
        for b in r.scan_batches(batch_size=64):
            got.extend(_as_list(b["url"]))
        assert got == urls
        assert fsck(root).clean
        # resume: re-running the evolution completes it
        add_column(root, "rank", ColumnType("int64"), values_fn)
        r = CIFReader(root, columns=["rank"])
        got = []
        for b in r.scan_batches(batch_size=64):
            got.extend(_as_list(b["rank"]))
        assert got == [v for si in range(CRASH_SPLITS)
                       for v in values_fn(si, CRASH_RECORDS)]
        assert fsck(root).clean
        # rewind for the next crash point
        shutil.rmtree(root)
        w = COFWriter(root, urlinfo_schema(), split_records=CRASH_RECORDS)
        w.append_all(records)
        w.close()


# -- scrubber classification (tentpole layer 2) -------------------------------


def test_fsck_classifies_each_damage_type(tmp_path):
    root = build_crawl(str(tmp_path / "d"), n=200)
    assert fsck(root).clean
    # corrupt: flip one byte of split 0's url.col
    p0 = os.path.join(root, "split-00000", "url.col")
    raw = bytearray(open(p0, "rb").read())
    raw[len(raw) // 2] ^= 0x20
    open(p0, "wb").write(bytes(raw))
    # torn: truncate split 1's metadata.col
    p1 = os.path.join(root, "split-00001", "metadata.col")
    blob = open(p1, "rb").read()
    open(p1, "wb").write(blob[: len(blob) // 2])
    # missing: delete split 2's srcUrl.col
    os.remove(os.path.join(root, "split-00002", "srcUrl.col"))

    report = fsck(root)
    assert not report.clean
    states = {(d.split_id, d.file): d.state for d in report.damage}
    assert states == {
        (0, "url.col"): "corrupt",
        (1, "metadata.col"): "torn",
        (2, "srcUrl.col"): "missing",
    }
    assert (report.copies_corrupt, report.copies_torn,
            report.copies_missing) == (1, 1, 1)
    # deterministic: two audits render identically
    assert fsck(root).format() == report.format()


def test_fsck_accepts_legacy_markerless_corpus(tmp_path):
    """A pre-PR-7 corpus (no commit markers anywhere) stays visible and
    audits clean via the containers' embedded v3.2 checksums."""
    root = build_crawl(str(tmp_path / "d"), n=150)
    for i, sdir in list_splits(root):
        os.remove(os.path.join(sdir, cof.COMMIT_MARKER))
    assert len(list_splits(root)) == 3
    report = fsck(root)
    assert report.clean and report.splits_scanned == 3
    # ... and damage is still detected without a manifest
    p = os.path.join(root, "split-00000", "url.col")
    raw = bytearray(open(p, "rb").read())
    raw[len(raw) // 2] ^= 0x20
    open(p, "wb").write(bytes(raw))
    bad = fsck(root)
    assert not bad.clean
    assert bad.damage[0].state in ("corrupt", "torn")


def test_uncommitted_debris_is_invisible_but_reported(tmp_path):
    root = build_crawl(str(tmp_path / "d"), n=150)
    # leftover building dir + a markerless final dir in a marker-era corpus
    os.makedirs(os.path.join(root, ".split-00009.building"))
    shutil.copytree(os.path.join(root, "split-00000"),
                    os.path.join(root, "split-00007"))
    os.remove(os.path.join(root, "split-00007", cof.COMMIT_MARKER))
    assert [i for i, _ in list_splits(root)] == [0, 1, 2]
    report = fsck(root)
    assert report.clean
    assert report.uncommitted == [".split-00009.building", "split-00007"]


# -- repair: heal, quarantine, release (tentpole layer 2) ---------------------


def test_repair_heals_faultplan_corruption_via_overlay(tmp_path):
    root = build_crawl(str(tmp_path / "d"))
    p = Placement(N_SPLITS, N_HOSTS, replication=2)
    hA = p.replicas(2)[0]
    plan = FaultPlan(corrupt_blocks=frozenset({(hA, 2, "url", 0)}))
    r1 = repair(root, p, fault_plan=plan)
    assert not r1.clean
    assert r1.repaired == [(2, "url.col", hA)]
    assert [(d.split_id, d.file, d.host, d.state) for d in r1.damage] == [
        (2, "url.col", hA, "corrupt")]
    # the healed copy lives in the overlay and reads clean THROUGH the plan
    assert os.path.exists(
        os.path.join(root, "split-00002", "_replicas", f"h{hA}", "url.col"))
    r2 = repair(root, p, fault_plan=plan)
    assert r2.clean and not r2.repaired
    assert repair(root, p, fault_plan=plan) == r2  # deterministic


def test_repair_quarantines_and_releases(tmp_path):
    root = build_crawl(str(tmp_path / "d"))
    p = Placement(N_SPLITS, N_HOSTS, replication=2)
    target = os.path.join(root, "split-00003", "url.col")
    good = open(target, "rb").read()
    bad = bytearray(good)
    bad[len(bad) // 2] ^= 0x10
    open(target, "wb").write(bytes(bad))
    # physical base damage = every replica copy damaged: zero clean sources
    r1 = repair(root, p)
    assert r1.quarantined == [3] and not r1.repaired
    assert quarantined_splits(root) == [3]
    assert [i for i, _ in list_splits(root)] == [0, 1, 2, 4, 5]
    assert "QUARANTINED" in format_storage_report(root)
    # a quarantined split is repeatable, not flapping
    assert repair(root, p).quarantined == [3]
    # restore the bytes (operator restores from backup): full scrub releases
    open(target, "wb").write(good)
    r2 = repair(root, p)
    assert r2.clean and r2.released == [3]
    assert quarantined_splits(root) == []
    assert len(list_splits(root)) == N_SPLITS


def test_repair_rewrites_physically_damaged_base_from_overlay(tmp_path):
    """Physical base damage IS healable once any clean per-host copy
    exists: repair prefers healing the base in place (durable replace)."""
    root = build_crawl(str(tmp_path / "d"))
    p = Placement(N_SPLITS, N_HOSTS, replication=2)
    hA = p.replicas(1)[0]
    # first: fault-plan corruption seeds a clean overlay copy for hA
    plan = FaultPlan(corrupt_blocks=frozenset({(hA, 1, "url", 0)}))
    repair(root, p, fault_plan=plan)
    # now: the base file takes real damage
    target = os.path.join(root, "split-00001", "url.col")
    good = open(target, "rb").read()
    bad = bytearray(good)
    bad[len(bad) // 3] ^= 0x40
    open(target, "wb").write(bytes(bad))
    r = repair(root, p, fault_plan=plan)
    assert (1, "url.col", -1) in r.repaired  # base healed in place
    assert open(target, "rb").read() == good  # bit-identical restoration
    assert not r.quarantined
    assert fsck(root).clean


# -- E2E: repair restores coverage (the PR's acceptance scenario) -------------


def test_repair_restores_coverage_bit_identically(tmp_path):
    """One replica's copy is corrupt (seeded byte flip); the only other
    replica can't serve the column (IO errors).  PR 6 alone: every attempt
    fails -> re-execution budget exhausted -> ``CoverageError``.  After
    ``repair()`` healed the corrupt copy, the same doomed plan completes
    with output, remote_reads, and pre-existing ScanStats bit-identical to
    the no-fault serial run."""
    root = build_crawl(str(tmp_path / "d"))
    p2 = Placement(N_SPLITS, N_HOSTS, replication=2)
    S = 1
    hA, hB = p2.replicas(S)
    base, base_stats, _ = _run(root, placement=p2)

    damage = FaultPlan(corrupt_blocks=frozenset({(hA, S, "url", 0)}))
    doomed = FaultPlan(corrupt_blocks=frozenset({(hA, S, "url", 0)}),
                       io_errors=frozenset({(hB, S, "url")}))
    # PR 6 alone: corruption on one replica + unreachable other = dead job
    with pytest.raises(CoverageError) as ei:
        _run(root, doomed, POLICY, placement=p2)
    assert isinstance(ei.value, SplitUnserveableError)
    assert isinstance(ei.value, SplitRetryExhausted)  # old contract holds

    # heal while hB is still reachable: hA gets a clean overlay copy
    rep = repair(root, p2, fault_plan=damage)
    assert rep.repaired == [(S, "url.col", hA)]

    # the formerly-doomed plan now completes — served entirely by hA's
    # healed copy, so not a single retry, failover, or checksum failure
    for n_workers in (1, 4):
        res, stats, _ = _run(root, doomed, POLICY, n_workers=n_workers,
                             placement=p2)
        assert res.output == base.output
        assert res.remote_reads == base.remote_reads == 0
        assert _pre_existing(stats) == _pre_existing(base_stats)
        assert stats.checksum_failures == 0
        assert stats.read_retries == 0
        assert stats.splits_reexecuted == 0
        assert stats.repairs_enqueued == 0


def test_quarantine_downgrades_coverage_error_to_partial_job(tmp_path):
    """When NO clean copy exists the split is lost — but the corpus is
    not: quarantine removes it from the visible split set, so jobs over
    the reader's splits() complete instead of dying with CoverageError."""
    root = build_crawl(str(tmp_path / "d"))
    S = 4
    ids_without_S = [i for i in range(N_SPLITS) if i != S]
    expect, _, _ = _run(root, split_ids=ids_without_S)
    # physical damage to every copy (the base file backs all replicas)
    target = os.path.join(root, f"split-0000{S}", "url.col")
    raw = bytearray(open(target, "rb").read())
    raw[len(raw) // 2] ^= 0x08
    open(target, "wb").write(bytes(raw))
    with pytest.raises(CoverageError):
        _run(root, policy=POLICY)
    p = Placement(N_SPLITS, N_HOSTS, replication=2)
    assert repair(root, p).quarantined == [S]
    res, _, _ = _run(root, policy=POLICY)  # job_inputs skips the quarantined
    assert res.output == expect.output


# -- read-repair queue (tentpole layer 3) -------------------------------------


def test_scan_enqueues_corrupt_copies_deterministically(tmp_path):
    root = build_crawl(str(tmp_path / "d"))
    p = Placement(N_SPLITS, N_HOSTS)
    plan = FaultPlan(
        corrupt_blocks=frozenset({(p.primary(1), 1, "url", 0),
                                  (p.primary(4), 4, "metadata", 0)}),
    )
    expected_queue = {(1, "url", p.primary(1)), (4, "metadata", p.primary(4))}
    queues = []
    for n_workers in (1, 4, 1):
        res, stats, _ = _run(root, plan, POLICY, n_workers=n_workers)
        assert stats.repair_queue == expected_queue
        assert stats.repairs_enqueued == 2
        queues.append(sorted(stats.repair_queue))
    assert queues[0] == queues[1] == queues[2]

    # draining the queue scrubs ONLY the observed copies, heals them, and
    # the rerun is failure-free
    rep = repair(root, p, fault_plan=plan, queue=queues[0])
    assert rep.splits_scanned == 2
    assert sorted(rep.repaired) == [(1, "url.col", p.primary(1)),
                                    (4, "metadata.col", p.primary(4))]
    base, base_stats, _ = _run(root)
    res, stats, _ = _run(root, plan, POLICY)
    assert res.output == base.output
    assert stats.checksum_failures == 0 and stats.repairs_enqueued == 0
    assert _pre_existing(stats) == _pre_existing(base_stats)


def test_io_errors_do_not_enqueue_repairs(tmp_path):
    """Transient unreachability is not media damage: IO errors fail over
    but must never queue a healthy copy for re-replication."""
    root = build_crawl(str(tmp_path / "d"))
    p = Placement(N_SPLITS, N_HOSTS)
    plan = FaultPlan(io_errors=frozenset({(p.primary(2), 2, "url")}))
    res, stats, _ = _run(root, plan, POLICY)
    assert stats.read_retries > 0  # the fault did fire
    assert stats.repairs_enqueued == 0 and stats.repair_queue == set()


def test_prompt_store_records_repairs_on_serving_failure(tmp_path):
    from repro.data.tokens import TokenCorpus, TokenCorpusWriter
    from repro.launch.load_data import synth_token_docs
    from repro.serving.engine import PromptStore

    root = str(tmp_path / "corpus")
    w = TokenCorpusWriter(root, seq_len=32, split_records=16)
    for toks, meta in synth_token_docs(40, vocab=120, seed=3):
        w.add_document(toks % 50 + 1, meta)
    w.close()
    n_splits = len(list_splits(root))
    p = Placement(n_splits, N_HOSTS, replication=2)

    threshold = POLICY.max_attempts + 2  # exhaust epoch 0, clean at epoch 1
    plan = FaultPlan(corrupt_until={(0, "tokens"): threshold})
    corpus = TokenCorpus(root, placement=p, fault_plan=plan,
                         failure_policy=POLICY)
    store = PromptStore(corpus, max_prompt=5, policy=POLICY)
    store.fetch([(0, 3), (1, 7), (0, 9)])
    # the failed epoch's observations survived the discarded split reader
    assert store.stats.repairs_enqueued == len(store.stats.repair_queue) > 0
    assert {(s, c) for s, c, _ in store.stats.repair_queue} == {(0, "tokens")}
    assert {h for _, _, h in store.stats.repair_queue} <= set(p.replicas(0))

    # a second identical store observes the identical queue (determinism)
    store2 = PromptStore(
        TokenCorpus(root, placement=p, fault_plan=plan, failure_policy=POLICY),
        max_prompt=5, policy=POLICY)
    store2.fetch([(0, 3), (1, 7), (0, 9)])
    assert store2.stats.repair_queue == store.stats.repair_queue
