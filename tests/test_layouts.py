"""Per-replica heterogeneous layouts + layout-aware scheduling (PR 10).

The HAIL idea on COF: each replica of a split may carry a different sort
order at zero extra storage cost, and the scheduler routes a ``where=``
job to the best-layout replica per split, falling back to ANY replica
for correctness.  The load-bearing invariant — the differential harness:

    forced replica k  ==  forced replica 0  ==  layout-oblivious oracle

bit-identical, serial and concurrent, clean and faulted, and the chosen
layout never scans more blocks than the insertion-order fallback."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro.core import (
    CIFReader, COFWriter, ColumnFileReader, ColumnFormat, FailurePolicy,
    FaultPlan, INT64, LayoutDescriptor, Placement, STRING, Schema, col,
    explain, fsck, host_layout_dir, materialize_layouts, read_layouts,
    repair, split_name, urlinfo_schema,
)
from repro.core.layout import ROWIDS_FILE, materialize_split_layout
from repro.core.mapreduce import run_job
from conftest import make_crawl_records

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
POLICY = FailurePolicy(max_attempts=3, max_reexecutions=2)


def _as_list(vals):
    return vals.tolist() if hasattr(vals, "tolist") else list(vals)


# -- the k/v corpus: multi-block splits where sorting visibly wins ------------
# 1024 random keys in [0, 10000), 4 splits of 256 records, plain encoding
# with 32-record value blocks -> ~8 zone-mapped blocks per split.  Sorted by
# k, a range predicate touches ~1 block per split; in insertion order it
# touches nearly all of them.

KV_SCHEMA = Schema([("k", INT64()), ("v", STRING())])
N_ROWS, SPLIT_RECORDS = 1024, 256
N_SPLITS = N_ROWS // SPLIT_RECORDS
PRED = col("k") < 500


def _kv_records(n=N_ROWS, seed=7):
    import random

    rnd = random.Random(seed)
    for i in range(n):
        k = rnd.randrange(10000)
        yield {"k": k, "v": f"v{k}-{i}"}


def build_kv(root, layouts=("k",), n=N_ROWS, split_records=SPLIT_RECORDS,
             placement=None):
    w = COFWriter(root, KV_SCHEMA,
                  formats={"k": ColumnFormat(enc_block=32),
                           "v": ColumnFormat(enc_block=32)},
                  split_records=split_records)
    w.append_all(_kv_records(n))
    w.close()
    p = placement or Placement(N_SPLITS, n_hosts=3, replication=2)
    if layouts:
        materialize_layouts(root, p, list(layouts))
    return p


@pytest.fixture(scope="module")
def kv(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("layouts-kv") / "d")
    p = build_kv(root)
    return root, p


def _collect_batch(split_id, cols, emit):
    """Emit every matching row tagged with its CANONICAL identity
    ``(split, record id)`` — the strongest possible output-equality probe:
    any replica that serves rows in the wrong order or with the wrong
    identity changes the output."""
    ks, vs, rows = cols["k"], cols["v"], cols.rows
    for i in range(cols.n_rows):
        emit(None, (split_id, int(rows[i]), int(ks[i]), str(vs[i])))


def _collect_reduce(key, vals, emit):
    for v in sorted(vals):
        emit(key, v)


def _run_sched(root, pred, p, *, force=None, plan=None, policy=None,
               n_workers=1):
    r = CIFReader(root, columns=["k", "v"], fault_plan=plan,
                  failure_policy=policy)
    sched = r.schedule_layouts(pred, p)
    if force is not None:
        sched = sched.force(force)
    ids, ob = r.job_inputs(schedule=sched)
    res = run_job(ids, reduce_fn=_collect_reduce, n_hosts=p.n_hosts,
                  placement=sched.placement, open_split_batches=ob,
                  map_batch_fn=_collect_batch, n_workers=n_workers,
                  fault_plan=plan, failure_policy=policy, scan_stats=r.stats)
    return res, r.stats, sched


def _oracle(root, pred_py, p):
    """Layout-oblivious post-hoc filter: full scan of the BASE copies,
    predicate applied in plain Python on the map side."""
    r = CIFReader(root, columns=["k", "v"])
    ids, ob = r.job_inputs(batch_size=64, placement=p)

    def map_batch(split_id, cols, emit):
        ks, vs = cols["k"], cols["v"]
        for i in range(cols.n_rows):
            if pred_py(int(ks[i])):
                emit(None, (split_id, cols.start + i, int(ks[i]), str(vs[i])))

    res = run_job(ids, reduce_fn=_collect_reduce, n_hosts=p.n_hosts,
                  placement=p, open_split_batches=ob, map_batch_fn=map_batch,
                  scan_stats=r.stats)
    return res


# -- (a) forced replica k == replica 0 == layout-oblivious oracle -------------


def test_every_forced_replica_matches_the_oracle(kv):
    root, p = kv
    truth = _oracle(root, lambda k: k < 500, p).output
    assert truth  # the predicate actually selects rows
    repl = len(p.replicas(0))
    for n_workers in (1, 4):
        outs = []
        for k in range(repl):
            res, stats, sched = _run_sched(root, PRED, p, force=k,
                                           n_workers=n_workers)
            outs.append(res.output)
            # forcing pins every split to ONE chain position; attribution
            # is all-or-nothing per the position's layout
            assert stats.layout_best_choices + stats.layout_fallbacks \
                == N_SPLITS
        assert all(o == truth for o in outs), f"n_workers={n_workers}"
    # and the scheduler's own (unforced) choice agrees too
    res, stats, sched = _run_sched(root, PRED, p)
    assert res.output == truth
    assert res.remote_reads == 0  # chosen host always holds the copy it reads


def test_scheduler_prefers_the_sorted_copy_when_it_wins(kv):
    root, p = kv
    _, stats, sched = _run_sched(root, PRED, p)
    for s in sorted(sched.prefs):
        chosen = sched.chosen(s)
        assert chosen.sort_by == "k", f"split {s} did not pick the sorted copy"
    assert stats.layout_best_choices == N_SPLITS
    assert stats.layout_fallbacks == 0
    # the win is real: strictly fewer blocks scanned than the fallback
    _, fb_stats, _ = _run_sched(root, PRED, p, force=0)
    assert stats.blocks_pruned_stats > fb_stats.blocks_pruned_stats
    assert stats.bytes_decoded < fb_stats.bytes_decoded


# -- (b) chosen layout never scans more blocks than the fallback --------------


def test_chosen_never_scans_more_blocks_than_fallback(kv):
    root, p = kv
    r = CIFReader(root, columns=["k", "v"])
    # a slate of predicates: clustered, anti-clustered, point, and one the
    # sort column cannot help with (v is not a layout sort key)
    preds = [PRED, col("k") >= 9000, col("k") == 1234,
             (col("k") > 100) & (col("k") < 200), col("v").contains("v1")]
    for pred in preds:
        sched = r.schedule_layouts(pred, p)
        for s in sorted(sched.prefs):
            chosen, fb = sched.chosen(s), sched.fallback(s)
            assert chosen.blocks_scanned <= fb.blocks_scanned, (pred, s)


def test_tie_goes_to_the_insertion_order_base(kv):
    root, p = kv
    r = CIFReader(root, columns=["k", "v"])
    # v is not sorted on any replica: every candidate scans the same
    # blocks, so chain position 0 (the base copy) must win the tie
    sched = r.schedule_layouts(col("v").contains("v1"), p)
    for s in sorted(sched.prefs):
        assert sched.chosen(s).is_fallback, s


# -- explain composes with the schedule ---------------------------------------


def test_explain_reports_chosen_layout_and_matching_prune_counts(kv):
    root, p = kv
    rep = explain(root, PRED, columns=["k", "v"], placement=p)
    _, stats, sched = _run_sched(root, PRED, p)
    assert rep.blocks_pruned == stats.blocks_pruned_stats
    for se in rep.splits:
        assert se.layout_host == sched.chosen(se.split_id).host
        assert se.layout_sort_by == "k"
        assert len(se.layout_candidates) == len(sched.prefs[se.split_id])
    txt = rep.format()
    assert "layout: host" in txt and "(k) chosen of" in txt
    assert "insertion-order" in txt  # the slate names the fallback too


# -- (c) the PR 6 fault ladder crossing replicas of different layouts ---------


def test_cross_layout_failover_is_bit_identical(kv):
    root, p = kv
    clean, clean_stats, sched = _run_sched(root, PRED, p)
    victim = sched.chosen(1)
    assert victim.sort_by == "k"
    # physical-read corruption on the chosen SORTED copy of split 1: the
    # pinned attempt ladder exhausts there (single-host chain), the split
    # requeues, and epoch 1 serves the next candidate — a replica with a
    # DIFFERENT layout (the insertion-order base)
    plan = FaultPlan(corrupt_blocks=frozenset({(victim.host, 1, "k", 0)}))
    for n_workers in (1, 4):
        res, stats, _ = _run_sched(root, PRED, p, plan=plan, policy=POLICY,
                                   n_workers=n_workers)
        assert res.output == clean.output, f"n_workers={n_workers}"
        assert res.splits_reexecuted == 1
        assert stats.layout_best_choices == N_SPLITS - 1
        assert stats.layout_fallbacks == 1  # the re-execution's serving copy
    # determinism across schedules: counters agree serial vs concurrent
    s1 = _run_sched(root, PRED, p, plan=plan, policy=POLICY, n_workers=1)[1]
    s4 = _run_sched(root, PRED, p, plan=plan, policy=POLICY, n_workers=4)[1]
    assert vars(s1) == vars(s4)


def test_faulted_fallback_chain_exhaustion_surfaces(kv):
    root, p = kv
    r = CIFReader(root, columns=["k", "v"])
    sched = r.schedule_layouts(PRED, p)
    # damage EVERY candidate of split 0 beyond the re-execution budget
    blocks = frozenset(
        (c.host, 0, "k", 0) for c in sched.prefs[0]
    )
    from repro.core import CorruptFileError, SplitRetryExhausted

    with pytest.raises((SplitRetryExhausted, CorruptFileError)):
        _run_sched(root, PRED, p,
                   plan=FaultPlan(corrupt_blocks=blocks), policy=POLICY)


# -- materialization: deterministic, sorted, invertible -----------------------


def test_materialize_split_layout_is_deterministic_and_invertible(kv):
    root, _ = kv
    sdir = os.path.join(root, split_name(0))
    schema = KV_SCHEMA
    desc = LayoutDescriptor(sort_by="k")
    files1, meta1 = materialize_split_layout(sdir, schema, desc)
    files2, meta2 = materialize_split_layout(sdir, schema, desc)
    assert files1.keys() == files2.keys()
    for fname in files1:  # byte-identical rebuild — the repair acceptance rule
        assert files1[fname] == files2[fname], fname
    assert meta1 == meta2 and meta1["layout"] == desc.to_json()
    sorted_k = _as_list(
        ColumnFileReader(files1["k.col"], INT64()).read_range(
            0, meta1["n_records"]))
    assert sorted_k == sorted(sorted_k)
    rowids = _as_list(
        ColumnFileReader(files1[ROWIDS_FILE], INT64()).read_range(
            0, meta1["n_records"]))
    assert sorted(rowids) == list(range(meta1["n_records"]))  # a permutation
    base_k = _as_list(ColumnFileReader(
        open(os.path.join(sdir, "k.col"), "rb").read(), INT64()
    ).read_range(0, meta1["n_records"]))
    assert [base_k[i] for i in rowids] == sorted_k  # invertible


def test_unsortable_column_is_rejected():
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "d")
        w = COFWriter(root, urlinfo_schema(), split_records=32)
        w.append_all(make_crawl_records(40))
        w.close()
        with pytest.raises(AssertionError, match="sortable"):
            materialize_layouts(root, Placement(2, 3, 2), ["metadata"])


def test_layouts_need_room_in_the_replica_chain(kv_tmp=None):
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "d")
        build_kv(root, layouts=())
        with pytest.raises(AssertionError, match="replica chain"):
            # replication 2 leaves one non-base slot; two layouts don't fit
            materialize_layouts(root, Placement(N_SPLITS, 3, 2), ["k", "v"])


# -- the sidecar is advisory: correctness never depends on it -----------------


def test_unparseable_sidecar_falls_back_but_stays_correct(tmp_path):
    root = str(tmp_path / "d")
    p = build_kv(root)
    truth = _oracle(root, lambda k: k < 500, p).output
    sdir = os.path.join(root, split_name(2))
    marker = os.path.join(sdir, "_layout.json")
    with open(marker, "w") as f:
        f.write('{"v": 1, "algo": "crc32c", "hosts": {TRUNC')
    assert read_layouts(sdir) == {}
    report = fsck(root)
    assert not report.clean  # fsck names the unreadable sidecar...
    assert any(c.file == "_layout.json" for c in report.damage)
    res, stats, sched = _run_sched(root, PRED, p)
    assert res.output == truth  # ...but the scan just uses the base copy
    assert sched.chosen(2).is_fallback
    assert stats.layout_fallbacks >= 1


# -- repair x layouts: heal by RE-MATERIALIZING in the copy's own order -------


def test_repair_rematerializes_the_only_sorted_replica(tmp_path):
    root = str(tmp_path / "d")
    p = build_kv(root)
    r = CIFReader(root, columns=["k", "v"])
    sched0 = r.schedule_layouts(PRED, p)
    target = 2
    chosen = sched0.chosen(target)
    assert chosen.sort_by == "k"
    ldir = host_layout_dir(os.path.join(root, split_name(target)), chosen.host)
    kpath = os.path.join(ldir, "k.col")
    with open(kpath, "rb") as f:
        good = f.read()
    bad = bytearray(good)
    bad[len(bad) // 2] ^= 0xFF
    with open(kpath, "wb") as f:
        f.write(bytes(bad))

    report = fsck(root)
    assert any(f"_layouts/h{chosen.host}/k.col" == c.file
               for c in report.damage)
    # with its only sorted copy damaged the scheduler must fall back...
    sched1 = CIFReader(root, columns=["k", "v"]).schedule_layouts(PRED, p)
    assert sched1.chosen(target).is_fallback
    # ...and NEVER quarantine: the base copy still serves the split
    rep = repair(root, p)
    assert rep.quarantined == []
    assert any(s == target and f == f"_layouts/h{chosen.host}/k.col"
               for s, f, _h in rep.repaired)
    assert fsck(root).clean
    # healed copy is re-materialized SORTED (not byte-copied from the
    # insertion-order base): identical to the original sorted bytes
    with open(kpath, "rb") as f:
        healed = f.read()
    assert healed == good
    base_k = open(os.path.join(root, split_name(target), "k.col"), "rb").read()
    assert healed != base_k
    # and the scheduler picks the sorted copy again
    sched2 = CIFReader(root, columns=["k", "v"]).schedule_layouts(PRED, p)
    assert sched2.chosen(target).host == chosen.host
    assert sched2.chosen(target).sort_by == "k"
    # output unchanged throughout
    truth = _oracle(root, lambda k: k < 500, p).output
    assert _run_sched(root, PRED, p)[0].output == truth


def test_repair_heals_faultplan_layout_damage_via_overlay(tmp_path):
    root = str(tmp_path / "d")
    p = build_kv(root)
    sched = CIFReader(root, columns=["k", "v"]).schedule_layouts(PRED, p)
    chosen = sched.chosen(0)
    plan = FaultPlan(corrupt_blocks=frozenset({(chosen.host, 0, "k", 0)}))
    rep = repair(root, p, fault_plan=plan)
    assert any(s == 0 and f == f"_layouts/h{chosen.host}/k.col"
               and h == chosen.host for s, f, h in rep.repaired)
    ldir = host_layout_dir(os.path.join(root, split_name(0)), chosen.host)
    overlay = os.path.join(ldir, "_replicas", f"h{chosen.host}", "k.col")
    assert os.path.exists(overlay)
    # the healed overlay serves THROUGH the plan: the faulted scheduled
    # run now matches the clean one with no re-execution
    clean = _run_sched(root, PRED, p)[0]
    res, stats, _ = _run_sched(root, PRED, p, plan=plan, policy=POLICY)
    assert res.output == clean.output and res.splits_reexecuted == 0
    assert repair(root, p, fault_plan=plan).repaired == []  # idempotent


# -- heterogeneous 2-layout corpus on the paper's schema ----------------------


@pytest.fixture(scope="module")
def crawl2(tmp_path_factory):
    import random

    root = str(tmp_path_factory.mktemp("layouts-crawl") / "d")
    # shuffle: synth fetchTime is monotone in record order, which would
    # make the base copy already-sorted (ties -> base, nothing to test)
    records = make_crawl_records(500)
    random.Random(11).shuffle(records)
    w = COFWriter(root, urlinfo_schema(),
                  formats={"fetchTime": ColumnFormat(enc_block=16),
                           "url": ColumnFormat(enc_block=16)},
                  split_records=100)
    w.append_all(records)
    w.close()
    p = Placement(5, n_hosts=4, replication=3)
    assigned = materialize_layouts(root, p, ["fetchTime", "url"])
    return root, p, assigned


def test_two_heterogeneous_layouts_register_and_roundtrip(crawl2):
    root, p, assigned = crawl2
    for s in range(5):
        chain = p.replicas(s)
        layouts = read_layouts(os.path.join(root, split_name(s)))
        assert set(layouts) == {chain[1], chain[2]}
        assert layouts[chain[1]]["descriptor"].sort_by == "fetchTime"
        assert layouts[chain[2]]["descriptor"].sort_by == "url"
        assert assigned[s][chain[1]].sort_by == "fetchTime"
    assert fsck(root).clean


def test_predicate_routes_to_the_matching_sort_order(crawl2):
    root, p, _ = crawl2
    r = CIFReader(root, columns=["url"])
    # collect a mid-range fetchTime threshold from the data itself
    times, urls = [], []
    for sid, sdir in r.splits():
        sr = r.open_split(sdir, extra_columns=["fetchTime", "url"],
                          split_id=sid)
        times.extend(_as_list(sr.readers["fetchTime"].read_range(
            0, sr.n_records)))
        urls.extend(_as_list(sr.readers["url"].read_range(0, sr.n_records)))
        r.absorb_stats(sr)
    t_lo = sorted(times)[len(times) // 8]
    u_lo = sorted(urls)[len(urls) // 8]  # a pivot INSIDE the url range
    sched_t = CIFReader(root, columns=["url"]).schedule_layouts(
        col("fetchTime") < t_lo, p)
    sched_u = CIFReader(root, columns=["url"]).schedule_layouts(
        col("url") < u_lo, p)
    t_sorted = sum(1 for s in sched_t.prefs
                   if sched_t.chosen(s).sort_by == "fetchTime")
    u_sorted = sum(1 for s in sched_u.prefs
                   if sched_u.chosen(s).sort_by == "url")
    # each predicate finds its own sort order on a majority of splits
    assert t_sorted >= 3, sched_t.prefs
    assert u_sorted >= 3, sched_u.prefs
    # and the monotonicity bound holds for both
    for sched in (sched_t, sched_u):
        for s in sched.prefs:
            assert sched.chosen(s).blocks_scanned \
                <= sched.fallback(s).blocks_scanned


def test_forced_replicas_match_on_the_crawl_schema(crawl2):
    root, p, _ = crawl2
    pred = col("url").contains("ibm.com/jp")

    def run(force=None, n_workers=1):
        r = CIFReader(root, columns=["url", "metadata"])
        sched = r.schedule_layouts(pred, p)
        if force is not None:
            sched = sched.force(force)
        ids, ob = r.job_inputs(schedule=sched)

        def map_batch(split_id, cols, emit):
            rows = cols.rows
            for i, ct in enumerate(cols.sparse(
                    "metadata", range(cols.n_rows), key="content-type")):
                emit(None, (split_id, int(rows[i]), str(cols["url"][i]), ct))

        return run_job(ids, reduce_fn=_collect_reduce, n_hosts=p.n_hosts,
                       placement=sched.placement, open_split_batches=ob,
                       map_batch_fn=map_batch, n_workers=n_workers,
                       scan_stats=r.stats)

    truth = run(force=0).output
    assert truth
    for k in (1, 2):
        assert run(force=k).output == truth, f"replica {k}"
    for n_workers in (1, 4):
        assert run(n_workers=n_workers).output == truth


# -- v3.3 fixtures in the compat matrix ---------------------------------------


def test_v33_fixtures_read_verify_and_match_expected():
    with open(os.path.join(FIXTURES, "v33_expected.json")) as f:
        exp = json.load(f)
    srt = ColumnFileReader(
        open(os.path.join(FIXTURES, "v33_sorted_int64.col"), "rb").read(),
        INT64())
    rid = ColumnFileReader(
        open(os.path.join(FIXTURES, "v33_rowids_int64.col"), "rb").read(),
        INT64())
    # v3.3 is a DATASET-level version (the _layout.json sidecar + _layouts/
    # copies); the column container is unchanged v3.2
    assert srt.format_version == rid.format_version == "3.2"
    assert srt.verify_checksums() == rid.verify_checksums() == "crc32c"
    got_sorted = _as_list(srt.read_range(0, srt.n))
    got_rowids = _as_list(rid.read_range(0, rid.n))
    assert got_sorted == exp["sorted_int64"]
    assert got_rowids == exp["rowids_int64"]
    assert got_sorted == sorted(got_sorted)
    assert sorted(got_rowids) == list(range(rid.n))  # a permutation
    # the recorded base order inverts through the rowids
    assert [exp["base_int64"][i] for i in got_rowids] == got_sorted


def test_v33_layout_sidecar_fixture_parses():
    with open(os.path.join(FIXTURES, "v33_expected.json")) as f:
        exp = json.load(f)
    desc = LayoutDescriptor.from_json(exp["layout_descriptor"])
    assert desc.sort_by == "k"
    assert desc.to_json() == exp["layout_descriptor"]


# -- differential equality over generated corpus + predicate pairs -----------
# Hypothesis-driven where available; the same body also runs over a small
# deterministic grid so the property is exercised even without hypothesis.


def _check_differential(keys, pivot, op):
    pred = {"lt": col("k") < pivot, "ge": col("k") >= pivot,
            "eq": col("k") == pivot}[op]
    pred_py = {"lt": lambda k: k < pivot, "ge": lambda k: k >= pivot,
               "eq": lambda k: k == pivot}[op]
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "d")
        w = COFWriter(root, KV_SCHEMA,
                      formats={"k": ColumnFormat(enc_block=8),
                               "v": ColumnFormat(enc_block=8)},
                      split_records=32, fsync=False)
        w.append_all({"k": k, "v": f"v{k}-{i}"}
                     for i, k in enumerate(keys))
        w.close()
        n_splits = (len(keys) + 31) // 32
        p = Placement(n_splits, n_hosts=4, replication=3)
        materialize_layouts(root, p, ["k", "v"], fsync=False)
        truth = _oracle(root, pred_py, p).output
        r = CIFReader(root, columns=["k", "v"])
        sched = r.schedule_layouts(pred, p)
        for force in (None, 0, 1, 2):
            use = sched if force is None else sched.force(force)
            ids, ob = r.job_inputs(schedule=use)
            res = run_job(ids, reduce_fn=_collect_reduce, n_hosts=p.n_hosts,
                          placement=use.placement, open_split_batches=ob,
                          map_batch_fn=_collect_batch, scan_stats=r.stats)
            assert res.output == truth, f"force={force}"
        for s in sched.prefs:
            assert sched.chosen(s).blocks_scanned \
                <= sched.fallback(s).blocks_scanned


@pytest.mark.parametrize("seed,n,pivot,op", [
    (1, 8, 500, "lt"),        # single split, tiny
    (2, 70, 250, "ge"),       # three splits, anti-clustered
    (3, 120, 111, "eq"),      # point predicate
])
def test_differential_equality_grid(seed, n, pivot, op):
    import random

    rnd = random.Random(seed)
    _check_differential([rnd.randrange(1000) for _ in range(n)], pivot, op)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: the grid above still runs
    pass
else:
    @given(
        st.lists(st.integers(0, 999), min_size=8, max_size=120),
        st.integers(0, 999),
        st.sampled_from(["lt", "ge", "eq"]),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_differential_equality_under_layouts(keys, pivot, op):
        _check_differential(keys, pivot, op)
