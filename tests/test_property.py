"""Property-based tests (hypothesis) for the system's core invariants:
codec roundtrips over arbitrary typed values, skip-list positional access,
bit-packing, placement coverage, compaction kernels."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import ARRAY, BOOL, BYTES, FLOAT64, INT32, INT64, MAP, RECORD, STRING
from repro.core.colfile import ColumnFileReader, ColumnFileWriter, ColumnFormat
from repro.core.placement import Placement
from repro.core.schema import ColumnType, validate_value
from repro.core.varcodec import decode_cell, encode_cell, read_varint, skip_cell, write_varint
from repro.data.tokens import pack_bits, pack_codes, unpack_bits, unpack_codes

# -- strategies -------------------------------------------------------------

scalar_types = st.sampled_from(
    [INT32(), INT64(), FLOAT64(), STRING(), BYTES(), BOOL()]
)


def type_strategy(depth=2):
    if depth == 0:
        return scalar_types
    sub = type_strategy(depth - 1)
    return st.one_of(
        scalar_types,
        sub.map(ARRAY),
        sub.map(MAP),
        st.lists(sub, min_size=1, max_size=3).map(
            lambda ts: RECORD([(f"f{i}", t) for i, t in enumerate(ts)])
        ),
    )


def value_for(typ: ColumnType):
    k = typ.kind
    if k == "int32":
        return st.integers(-(2**31), 2**31 - 1)
    if k == "int64":
        return st.integers(-(2**63), 2**63 - 1)
    if k == "float64":
        return st.floats(allow_nan=False, width=64)
    if k == "string":
        return st.text(max_size=40)
    if k == "bytes":
        return st.binary(max_size=60)
    if k == "bool":
        return st.booleans()
    if k == "array":
        return st.lists(value_for(typ.elem), max_size=5)
    if k == "map":
        return st.dictionaries(st.text(max_size=8), value_for(typ.value), max_size=5)
    if k == "record":
        return st.fixed_dictionaries({f: value_for(t) for f, t in typ.fields})
    raise AssertionError(k)


typed_values = type_strategy().flatmap(
    lambda t: st.tuples(st.just(t), value_for(t))
)


# -- properties ---------------------------------------------------------------


@given(st.integers(-(2**63), 2**63 - 1))
def test_varint_roundtrip(n):
    buf = bytearray()
    write_varint(buf, n)
    got, off = read_varint(bytes(buf), 0)
    assert got == n and off == len(buf)


@given(typed_values)
@settings(max_examples=200, deadline=None)
def test_cell_roundtrip_and_skip(tv):
    typ, v = tv
    assert validate_value(typ, v)
    buf = bytearray()
    encode_cell(typ, v, buf)
    got, end = decode_cell(typ, bytes(buf), 0)
    assert end == len(buf)
    skipped_end = skip_cell(typ, bytes(buf), 0)
    assert skipped_end == len(buf)
    if typ.kind == "float64":
        assert got == v or (np.isclose(got, v))
    else:
        assert got == v


@given(
    st.lists(st.integers(0, 10**6), min_size=1, max_size=300),
    st.data(),
)
@settings(max_examples=50, deadline=None)
def test_skiplist_positional_access(vals, data):
    w = ColumnFileWriter(INT64(), ColumnFormat("skiplist"))
    for v in vals:
        w.append(v)
    r = ColumnFileReader(w.finish(), INT64())
    # any monotone access pattern must return exact values
    idxs = sorted(
        data.draw(st.sets(st.integers(0, len(vals) - 1), max_size=20))
    )
    for i in idxs:
        assert r.value_at(i) == vals[i]


@given(st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=500),
       st.sampled_from([4, 8, 16]))
def test_pack_unpack_codes(codes, bits):
    codes = [c % (1 << bits) for c in codes]
    arr = np.asarray(codes, np.uint32)
    raw = pack_codes(arr, bits)
    back = unpack_codes(raw, bits, len(codes))
    assert back.tolist() == codes


@given(st.lists(st.booleans(), min_size=1, max_size=300))
def test_pack_unpack_bits(bits):
    arr = np.asarray(bits, bool)
    assert unpack_bits(pack_bits(arr), len(bits)).astype(bool).tolist() == bits


@given(st.integers(1, 200), st.integers(1, 32), st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_placement_total_coverage(n_splits, n_hosts, repl):
    p = Placement(n_splits, n_hosts, repl)
    r = min(repl, n_hosts)
    for s in range(n_splits):
        reps = p.replicas(s)
        assert len(reps) == r and len(set(reps)) == r
        assert all(0 <= h < n_hosts for h in reps)
    # union of per-host primary sets covers all splits exactly once
    seen = []
    for h in range(n_hosts):
        seen.extend(p.splits_of(h))
    assert sorted(seen) == list(range(n_splits))


# -- vectorized lexicographic compare (ISSUE 5) -----------------------------
# RaggedColumn.cmp must agree with Python's own bytes/str ordering for every
# (cells, pivot) pair — including empty cells, shared prefixes, multi-byte
# UTF-8, and the tie-break-on-length cases that a prefix compare gets wrong
# if it stops early.


def _ragged_from(cells, kind):
    raws = [c.encode("utf-8") if isinstance(c, str) else c for c in cells]
    buf = b"".join(raws)
    lengths = np.asarray([len(r) for r in raws], np.int64)
    starts = np.concatenate([[0], np.cumsum(lengths[:-1])]).astype(np.int64)
    from repro.core.varcodec import RaggedColumn

    return RaggedColumn(buf, starts, lengths, kind)


@given(st.lists(st.binary(max_size=12), min_size=1, max_size=60),
       st.binary(max_size=12))
@settings(max_examples=200, deadline=None)
def test_ragged_cmp_matches_bytes_ordering(cells, pivot):
    rc = _ragged_from(cells, "bytes")
    got = rc.cmp(pivot).tolist()
    expect = [(-1 if c < pivot else (0 if c == pivot else 1)) for c in cells]
    assert got == expect


@given(st.lists(st.text(max_size=8), min_size=1, max_size=40),
       st.text(max_size=8))
@settings(max_examples=200, deadline=None)
def test_ragged_cmp_matches_str_ordering(cells, pivot):
    # UTF-8 preserves code-point order, so byte compare == str compare
    rc = _ragged_from(cells, "string")
    got = rc.cmp(pivot).tolist()
    expect = [(-1 if c < pivot else (0 if c == pivot else 1)) for c in cells]
    assert got == expect


# -- sorted-replica layout round-trip (ISSUE 10) ------------------------------
# The per-replica heterogeneous layout write path must be a pure re-ordering:
# for ANY corpus, the sorted copy's values are the base values permuted by a
# stable sort, ``_rowids`` is the inverse permutation, and re-materializing
# is byte-deterministic (the repair acceptance rule).


@given(
    st.lists(st.tuples(st.integers(-(2**31), 2**31 - 1), st.text(max_size=12)),
             min_size=1, max_size=80),
    st.sampled_from(["k", "v"]),
)
@settings(max_examples=30, deadline=None)
def test_sorted_replica_layout_roundtrip(rows, sort_by):
    import os
    import tempfile

    from repro.core import COFWriter, ColumnFileReader, Schema, split_name
    from repro.core.colfile import ColumnFormat as CF
    from repro.core.layout import (
        LayoutDescriptor, ROWIDS_FILE, materialize_split_layout,
    )
    from repro.core.schema import INT64 as I64, STRING as STR

    schema = Schema([("k", I64()), ("v", STR())])
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "d")
        w = COFWriter(root, schema,
                      formats={"k": CF(enc_block=8), "v": CF(enc_block=8)},
                      split_records=len(rows), fsync=False)
        w.append_all({"k": k, "v": v} for k, v in rows)
        w.close()
        sdir = os.path.join(root, split_name(0))
        desc = LayoutDescriptor(sort_by=sort_by)
        files, meta = materialize_split_layout(sdir, schema, desc)
        again, _ = materialize_split_layout(sdir, schema, desc)
        assert files == again  # byte-deterministic rebuild
        n = meta["n_records"]
        rowids = _as_plain_list(
            ColumnFileReader(files[ROWIDS_FILE], I64()).read_range(0, n))
        assert sorted(rowids) == list(range(n))  # a permutation
        base = {"k": [k for k, _ in rows], "v": [v for _, v in rows]}
        for name in ("k", "v"):
            got = _as_plain_list(ColumnFileReader(
                files[f"{name}.col"], schema.type_of(name)).read_range(0, n))
            assert got == [base[name][i] for i in rowids]  # pure re-ordering
        key = _as_plain_list(ColumnFileReader(
            files[f"{sort_by}.col"], schema.type_of(sort_by)).read_range(0, n))
        assert key == sorted(key)
        # stable: equal keys keep insertion order
        assert rowids == sorted(range(n), key=lambda i: (base[sort_by][i], i))


def _as_plain_list(vals):
    return vals.tolist() if hasattr(vals, "tolist") else list(vals)


@given(st.lists(st.sampled_from(["", "a", "ab", "b", "ba", "bb"]),
                min_size=1, max_size=80),
       st.sampled_from(["", "a", "ab", "abc", "b", "c"]))
@settings(max_examples=100, deadline=None)
def test_dict_ragged_cmp_broadcasts_through_codes(cells, pivot):
    # dict views evaluate once per DISTINCT value and gather through codes
    from repro.core.varcodec import DictRaggedColumn

    uniq = sorted(set(cells))
    codes = np.asarray([uniq.index(c) for c in cells], np.int64)
    base = _ragged_from(uniq, "string")
    dc = DictRaggedColumn(base.buffer, base.starts, base.lengths, codes,
                          "string")
    got = dc.cmp(pivot).tolist()
    expect = [(-1 if c < pivot else (0 if c == pivot else 1)) for c in cells]
    assert got == expect
