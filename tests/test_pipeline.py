"""Data pipeline: determinism, resumability, projection pushdown, sharding."""
import os

import numpy as np
import pytest

from repro.core.placement import Placement
from repro.data.pipeline import HostPipeline
from repro.data.sampler import SamplerState, ShardedSampler
from repro.data.tokens import TokenCorpus, TokenCorpusWriter
from repro.launch.load_data import synth_token_docs


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    root = tmp_path_factory.mktemp("corpus")
    w = TokenCorpusWriter(str(root), seq_len=64, split_records=32)
    for toks, meta in synth_token_docs(150, vocab=2000):
        w.add_document(toks, meta)
    w.close()
    return TokenCorpus(str(root))


def test_corpus_roundtrip_decode_paths(corpus):
    sp = corpus.open_split(corpus.split_ids()[0])
    t_np, m = sp.record(0, decode="np")
    sp2 = corpus.open_split(corpus.split_ids()[0])
    t_py, m2 = sp2.record(0, decode="py")
    np.testing.assert_array_equal(t_np, t_py)
    np.testing.assert_array_equal(m, m2)
    assert t_np.shape == (64,) and t_np.dtype == np.int32


def test_pipeline_deterministic(corpus):
    def take(n):
        pipe = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=7)
        it = iter(pipe)
        return [next(it)["tokens"].copy() for _ in range(n)]

    a, b = take(6), take(6)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_pipeline_resume_matches_uninterrupted(corpus):
    pipe = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=7)
    it = iter(pipe)
    full = [next(it)["tokens"].copy() for _ in range(8)]
    # run 4, snapshot state, restore into a new pipeline
    pipe2 = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=7)
    it2 = iter(pipe2)
    for _ in range(4):
        next(it2)
    st = pipe2.state()
    pipe3 = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=7, state=st)
    it3 = iter(pipe3)
    for i in range(4, 8):
        np.testing.assert_array_equal(next(it3)["tokens"], full[i])


def test_pipeline_hosts_disjoint(corpus):
    seen = {}
    for host in range(3):
        s = ShardedSampler(
            {sid: len(corpus.open_split(sid)) for sid in corpus.split_ids()},
            Placement(len(corpus.split_ids()), 3),
            host,
        )
        it = iter(s)
        mine = set()
        # one full epoch for this host
        start_epoch = s.state.epoch
        while s.state.epoch == start_epoch:
            sid, rid = next(it)
            if s.state.epoch != start_epoch:
                break
            mine.add((sid, rid))
        seen[host] = mine
    all_pairs = set().union(*seen.values())
    assert sum(len(v) for v in seen.values()) == len(all_pairs)  # disjoint


def test_projection_pushdown_never_opens_meta(corpus):
    sid = corpus.split_ids()[0]
    sp = corpus.open_split(sid)
    assert set(sp.reader.readers) == {"tokens", "n_tokens", "loss_mask"}


def test_labels_are_shifted(corpus):
    pipe = HostPipeline(corpus, batch_per_host=2, prefetch=0)
    b = next(iter(pipe))
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["loss_mask"][:, -1] == 0).all()


def test_prefetch_thread_equivalent(corpus):
    p0 = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=3)
    p2 = HostPipeline(corpus, batch_per_host=4, prefetch=2, seed=3)
    it0, it2 = iter(p0), iter(p2)
    for _ in range(5):
        np.testing.assert_array_equal(next(it0)["tokens"], next(it2)["tokens"])
    p2.stop()
