"""Mini MapReduce executor + the paper's Fig. 1 job, incl. failure handling."""
import pytest

from repro.core import CIFReader, COFWriter, ColumnFormat, urlinfo_schema
from repro.core.mapreduce import fig1_map, fig1_reduce, run_job
from repro.core.placement import Placement
from conftest import make_crawl_records


@pytest.fixture(scope="module")
def crawl(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("crawl") / "d")
    records = make_crawl_records(1200)
    w = COFWriter(root, urlinfo_schema(),
                  formats={"metadata": ColumnFormat("dcsl"),
                           "url": ColumnFormat("skiplist")},
                  split_records=128)
    w.append_all(records)
    w.close()
    return root, records


def _open_split_fn(root):
    reader = CIFReader(root, columns=["url", "metadata"], lazy=True)
    split_map = dict(reader.splits())

    def open_split(sid):
        for rec in reader.open_split(split_map[sid]).iter_lazy():
            yield None, rec

    return list(split_map), open_split


def brute_force(records):
    return sorted({
        r["metadata"]["content-type"] for r in records if "ibm.com/jp" in r["url"]
    })


def test_fig1_job_correct(crawl):
    root, records = crawl
    ids, open_split = _open_split_fn(root)
    res = run_job(ids, open_split, fig1_map(), fig1_reduce, n_hosts=4)
    assert [v for _, v in res.output] == brute_force(records)
    assert res.remote_reads == 0  # CPP invariant
    assert res.splits_processed == len(ids)


def test_job_survives_dead_hosts(crawl):
    root, records = crawl
    ids, open_split = _open_split_fn(root)
    res = run_job(ids, open_split, fig1_map(), fig1_reduce,
                  n_hosts=5, dead_hosts={1, 3})
    assert [v for _, v in res.output] == brute_force(records)
    assert res.splits_processed == len(ids)
    live = {h for h in res.host_of_split.values()}
    assert live.isdisjoint({1, 3})


def test_job_fails_when_coverage_lost(crawl):
    root, records = crawl
    ids, open_split = _open_split_fn(root)
    p = Placement(n_splits=len(ids), n_hosts=3, replication=3)
    with pytest.raises(AssertionError):
        run_job(ids, open_split, fig1_map(), fig1_reduce,
                n_hosts=3, dead_hosts={0, 1, 2}, placement=p)


def test_combiner_reduces_shuffle(crawl):
    root, records = crawl
    ids, open_split = _open_split_fn(root)

    def combiner(key, vals, emit):
        for v in set(vals):
            emit(key, v)

    r0 = run_job(ids, open_split, fig1_map(), fig1_reduce, n_hosts=4)
    ids2, open_split2 = _open_split_fn(root)
    r1 = run_job(ids2, open_split2, fig1_map(), fig1_reduce, n_hosts=4,
                 combiner=combiner)
    assert [v for _, v in r0.output] == [v for _, v in r1.output]
    assert r1.map_output_records <= r0.map_output_records
