"""Training substrate: optimizer math, checkpoint atomicity/resume,
fault-tolerant restart determinism, gradient compression numerics."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.data.pipeline import HostPipeline
from repro.data.tokens import TokenCorpus, TokenCorpusWriter
from repro.distributed.sharding import default_sharding
from repro.launch.load_data import synth_token_docs
from repro.launch.mesh import make_host_mesh
from repro.training.checkpoint import Checkpointer
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, schedule
from repro.training.train_loop import TrainLoopConfig, fit


def _mk_corpus(path, n_docs=120, seq_len=64):
    w = TokenCorpusWriter(str(path), seq_len=seq_len, split_records=32)
    for toks, meta in synth_token_docs(n_docs, vocab=512):
        w.add_document(toks, meta)
    w.close()
    return TokenCorpus(str(path))


def _cfg(corpus):
    cfg = reduced(get_config("tinyllama-1.1b"))
    return dataclasses.replace(cfg, vocab_size=corpus.vocab_size, n_layers=2, d_model=32,
                               n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64)


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, opt, m = adamw_update(cfg, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 1.0) < 1e-6
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), max_keep=2)
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    for step in (5, 10, 15):
        ck.save(step, state, data_state={"cursor": step})
    assert ck.latest_step() == 15
    # gc kept only 2
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step-")]
    assert len(kept) == 2
    step, restored, ds = ck.restore(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    assert step == 15 and ds == {"cursor": 15}
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(state["a"]))
    assert str(restored["b"]["c"].dtype) == "bfloat16"


def test_checkpoint_crash_safety(tmp_path):
    """A half-written step dir must not be visible via LATEST."""
    ck = Checkpointer(str(tmp_path))
    state = {"a": jnp.zeros(3)}
    ck.save(1, state)
    # simulate crash: partial tmp dir left behind
    os.makedirs(os.path.join(str(tmp_path), "step-00000002.tmp-0"), exist_ok=True)
    assert ck.latest_step() == 1


def test_restart_resumes_identically(tmp_path):
    """Gold-standard fault tolerance test: an interrupted-and-resumed run
    must produce the SAME final loss as an uninterrupted run."""
    corpus = _mk_corpus(tmp_path / "corpus")
    cfg = _cfg(corpus)
    mesh = make_host_mesh()
    sh = default_sharding(cfg)
    shape = ShapeConfig("t", 64, 4, "train")

    def run(ckpt_dir, steps):
        pipe = HostPipeline(corpus, batch_per_host=4, prefetch=0)
        loop = TrainLoopConfig(steps=steps, ckpt_every=5, log_every=1,
                               ckpt_dir=str(ckpt_dir))
        return fit(cfg, mesh, sh, shape, pipe, loop)

    # uninterrupted 20 steps
    full = run(tmp_path / "ckpt_full", 20)
    # interrupted: 10 steps, then "crash", then resume to 20
    run(tmp_path / "ckpt_int", 10)
    resumed = run(tmp_path / "ckpt_int", 20)
    f = {m["step"]: m["loss"] for m in full["history"]}
    r = {m["step"]: m["loss"] for m in resumed["history"]}
    for s in range(11, 21):
        assert f[s] == pytest.approx(r[s], rel=1e-4), (s, f[s], r[s])


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore the same checkpoint under a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ck.save(3, state)
    mesh = make_host_mesh(model=1)  # 1 device; layout change is structural
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    step, restored, _ = ck.restore(
        {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}, shardings=shardings
    )
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_grad_compression_error_feedback():
    from repro.training.compression import ef_compress_tree, init_error

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    err = init_error(g)
    # accumulate compressed means over steps: with error feedback the
    # cumulative dequantized sum tracks the true sum closely
    true_sum = np.zeros(256)
    deq_sum = np.zeros(256)
    for step in range(50):
        gs = {"w": g["w"] * (1 + 0.01 * step)}
        q, s, err = ef_compress_tree(gs, err)
        true_sum += np.asarray(gs["w"])
        deq_sum += np.asarray(q["w"]).astype(np.float32) * float(s["w"])
    rel = np.abs(deq_sum - true_sum).max() / np.abs(true_sum).max()
    assert rel < 0.01, rel
