"""Predicate pushdown subsystem (zone maps + planner + where= pipeline).

* predicate algebra: every operator/combinator's vectorized ``mask`` and
  scalar ``matches_record`` agree with brute-force Python;
* the v3 writer emits zone maps for every stats-bearing kind and the reader
  plans on them WITHOUT decoding (prune moves no counter);
* dict pages and bloom filters prune what min/max cannot;
* the acceptance matrix: for predicate x encoding x kind combinations,
  ``scan_batches(where=p)`` and ``run_job(where=p)`` return row sets
  bit-identical to an unpruned scan filtered post hoc, with
  ``blocks_pruned_stats > 0`` on selective predicates over sorted/clustered
  columns and identical counters across serial vs concurrent runs;
* format compatibility: checked-in v1/v2/v3/v3.1 fixtures — old versions
  read bit-for-bit and plan as "scan everything" when stats are absent,
  and the v3.1 trailing sections are invisible to a v3-style parse;
* complex-type pushdown (ISSUE 5): map-key predicates over DCSL columns
  prune on key presence, fetch only the referenced key via ``lookup_many``
  (counters prove non-matching map cells are never decoded), and stay
  bit-identical to post-hoc filtering; vectorized lexicographic string
  ordering agrees with brute force; cblock stats-tags prune compressed
  blocks with zero inflate calls;
* the rewritten ``fig1_map_batch`` against the pre-pushdown hand-rolled
  implementation as an equivalence oracle.
"""
import json
import os

import numpy as np
import pytest

from repro.core import (
    CIFReader,
    COFWriter,
    ColumnFormat,
    col,
    fig1_map,
    fig1_map_batch,
    fig1_reduce,
    fig1_where,
    parse_predicate,
    run_job,
    storage_report,
    urlinfo_schema,
)
from repro.core.colfile import ColumnFileReader, ColumnFileWriter
from repro.core.predicate import TRI_ALL, TRI_NONE, TRI_SOME
from repro.core.schema import FLOAT64, INT64, MAP, STRING
from repro.core.stats import BloomFilter
from conftest import make_crawl_records

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _build(typ, fmt, vals):
    w = ColumnFileWriter(typ, fmt)
    for v in vals:
        w.append(v)
    return w.finish(), w


def _as_list(v):
    return v.tolist() if hasattr(v, "tolist") else list(v)


# -- predicate algebra --------------------------------------------------------


def test_predicate_masks_match_brute_force(rnd):
    n = 500
    ints = np.array([rnd.randint(0, 50) for _ in range(n)])
    strs = [rnd.choice(["text/html", "app/pdf", "img/png"]) for _ in range(n)]
    getcol = {"i": ints, "s": strs}.__getitem__
    cases = [
        (col("i") == 7, [v == 7 for v in ints]),
        (col("i") != 7, [v != 7 for v in ints]),
        (col("i") < 10, [v < 10 for v in ints]),
        (col("i") <= 10, [v <= 10 for v in ints]),
        (col("i") > 40, [v > 40 for v in ints]),
        (col("i") >= 40, [v >= 40 for v in ints]),
        (col("i").isin([1, 2, 3]), [v in (1, 2, 3) for v in ints]),
        (col("s") == "app/pdf", [v == "app/pdf" for v in strs]),
        (col("s").contains("pdf"), ["pdf" in v for v in strs]),
        (col("s").isin(["img/png", "app/pdf"]),
         [v in ("img/png", "app/pdf") for v in strs]),
        ((col("i") < 25) & col("s").contains("html"),
         [i < 25 and "html" in s for i, s in zip(ints, strs)]),
        ((col("i") < 5) | (col("i") > 45),
         [v < 5 or v > 45 for v in ints]),
        (~(col("s") == "img/png"), [v != "img/png" for v in strs]),
        (~((col("i") < 25) | col("s").contains("pdf")),
         [not (i < 25 or "pdf" in s) for i, s in zip(ints, strs)]),
    ]
    for pred, expect in cases:
        np.testing.assert_array_equal(
            pred.mask(getcol, n), np.array(expect), err_msg=repr(pred)
        )
        # scalar record evaluation agrees with the vectorized mask
        class Rec:
            def __init__(self, i):
                self.i = i

            def get(self, name):
                return int(ints[self.i]) if name == "i" else strs[self.i]

        for i in (0, 13, n - 1):
            assert pred.matches_record(Rec(i)) == expect[i], repr(pred)


def test_predicate_keyword_combinators_rejected():
    with pytest.raises(TypeError):
        bool(col("a") == 1)  # `and`/`or`/`not` would call __bool__


def test_column_vs_column_compare_rejected():
    with pytest.raises(AssertionError, match="column-vs-column"):
        col("a") == col("b")


def test_bytes_literal_on_string_column_consistent():
    """Every evaluator agrees on str/bytes mixes (UTF-8 semantics, like the
    vectorized RaggedColumn predicates)."""
    strs = ["ab", "cd", "xyz"]
    for pred, expect in [
        (col("s") == b"cd", [False, True, False]),
        (col("s").contains(b"y"), [False, False, True]),
        (col("s").isin([b"ab", "xyz"]), [True, False, True]),
    ]:
        np.testing.assert_array_equal(pred.mask(lambda _: strs, 3),
                                      np.array(expect), err_msg=repr(pred))

        class Rec:
            def __init__(self, i):
                self.i = i

            def get(self, name):
                return strs[self.i]

        assert [pred.matches_record(Rec(i)) for i in range(3)] == expect


def test_where_validates_literals_against_schema(tmp_path):
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=256)
    w.append_all(make_crawl_records(256))
    w.close()
    r = CIFReader(root, columns=["url"])
    # a typo'd numeric literal ("13OO") must fail loudly up front, not
    # scan to a silently empty result
    with pytest.raises(AssertionError, match="literal"):
        next(iter(r.scan_batches(where=parse_predicate("fetchTime == 13OO"))))
    with pytest.raises(AssertionError, match="unsupported"):
        next(iter(r.scan_batches(where=col("metadata") == "x")))
    with pytest.raises(AssertionError, match="string/bytes"):
        next(iter(r.scan_batches(where=col("fetchTime").contains("9"))))


def test_where_spans_expose_only_the_projection(tmp_path):
    """A predicate-only column never leaks into keys()/iteration — the
    where= span and an unfiltered scan of the same reader expose identical
    column sets (it stays fetchable by explicit name)."""
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=256)
    w.append_all(make_crawl_records(300))
    w.close()
    r = CIFReader(root, columns=["srcUrl"])
    ids, ob = r.job_inputs(batch_size=128, where=col("fetchTime") >= T0)
    fb = next(ob(ids[0]))
    assert list(fb) == fb.keys() == ["srcUrl"]
    assert "fetchTime" not in fb and fb.get("fetchTime") is None
    assert len(fb["fetchTime"]) == fb.n_rows  # explicit access still works


def test_parse_predicate():
    assert repr(parse_predicate("fetchTime >= 120")) == repr(col("fetchTime") >= 120)
    assert repr(parse_predicate("url contains ibm.com/jp")) == repr(
        col("url").contains("ibm.com/jp"))
    p = parse_predicate("lang == 'jp'")
    assert p.value == "jp" and p.op == "=="


# -- zone maps: writer emission + reader planning -----------------------------


def test_zone_maps_emitted_for_every_stats_kind(rnd):
    cases = [
        ("plain", INT64(), [rnd.randint(0, 9999) for _ in range(5000)]),
        ("cblock", INT64(), [rnd.randint(0, 9999) for _ in range(5000)]),
        ("plain", STRING(), [f"v{rnd.randint(0, 30):04d}" for _ in range(5000)]),
        ("skiplist", STRING(), [rnd.choice(["en", "jp", "de"]) for _ in range(5000)]),
        ("skiplist", FLOAT64(), [rnd.random() for _ in range(5000)]),  # streaming
    ]
    for kind, typ, vals in cases:
        fmt = ColumnFormat(kind, codec="zlib" if kind == "cblock" else "none")
        raw, _ = _build(typ, fmt, vals)
        r = ColumnFileReader(raw, typ)
        zms = r.block_stats()
        assert zms, (kind, typ.kind)
        assert sum(z.count for z in zms) == len(vals)
        # zone bounds are exact per block
        pos = 0
        for z in zms:
            assert z.first == pos
            block = vals[pos:pos + z.count]
            assert z.vmin == min(block) and z.vmax == max(block)
            assert z.n_distinct == len(set(block))
            pos += z.count
        # values unchanged by the footer
        assert _as_list(r.read_range(0, len(vals))) == vals
    # map columns carry bounds-free zone maps + key-presence stats-tags
    mvals = [{"k": "v"} for _ in range(100)]
    raw, _ = _build(MAP(STRING()), ColumnFormat("dcsl"), mvals)
    r = ColumnFileReader(raw, MAP(STRING()))
    (zm,) = r.block_stats()
    assert (zm.first, zm.count, zm.vmin, zm.vmax) == (0, 100, None, None)
    assert r.block_extras == [("keys", frozenset({"k"}))]
    assert r.format_version == "3.2"  # fresh files also carry checksums


def test_prune_is_advisory_and_decodes_nothing(rnd):
    vals = sorted(rnd.randint(0, 10**6) for _ in range(6000))
    raw, _ = _build(INT64(), ColumnFormat("plain"), vals)
    r = ColumnFileReader(raw, INT64())
    threshold = vals[100]
    pr = r.prune(col("x") <= threshold)
    assert pr.blocks_pruned >= 2 and pr.blocks_total == 3
    # every matching row id is inside the surviving ranges (soundness)
    matching = [i for i, v in enumerate(vals) if v <= threshold]
    for i in matching:
        assert any(a <= i < b for a, b in pr.ranges), i
    # planning is free: no counter moved, reader still usable from row 0
    assert vars(r.counters) == vars(ColumnFileReader(raw, INT64()).counters)
    assert _as_list(r.read_range(0, 10)) == vals[:10]
    # an unselective predicate keeps everything
    assert r.prune(col("x") >= 0).ranges == [(0, len(vals))]
    # tri-state sanity on the file-level aggregate
    info = lambda name: r.block_stats()[0].info()
    assert (col("x") == vals[0] - 1).tri(info) == TRI_NONE
    assert (col("x") >= vals[0] - 1).tri(info) == TRI_ALL
    assert (col("x") == vals[50]).tri(info) in (TRI_SOME, TRI_ALL)


def test_dict_page_prunes_what_minmax_cannot(rnd):
    # "bb" sits inside [aa, cc] lexically, but the dictionary knows better
    vals = [rnd.choice(["aa", "cc"]) for _ in range(4000)]
    raw, w = _build(STRING(), ColumnFormat("plain"), vals)
    assert set(w.encoding_stats()["blocks"]) == {"dict"}
    r = ColumnFileReader(raw, STRING())
    assert r.prune(col("s") == "bb").ranges == []
    assert r.prune(col("s").contains("b")).ranges == []
    assert r.prune(col("s").isin(["bb", "dd"])).ranges == []
    # NOT of an all-matching dictionary also prunes
    assert r.prune(~col("s").isin(["aa", "cc"])).ranges == []
    assert r.prune(col("s") == "cc").ranges == [(0, 4000)]


def test_bloom_prunes_absent_high_cardinality_value(rnd):
    # high-entropy strings: dict loses to plain, min/max spans everything —
    # only the bloom filter can rule out an absent needle
    vals = [f"{rnd.random():.12f}" for _ in range(3000)]
    raw, w = _build(STRING(), ColumnFormat("plain"), vals)
    assert set(w.encoding_stats()["blocks"]) == {"plain"}
    r = ColumnFileReader(raw, STRING())
    assert r.bloom is not None
    assert r.prune(col("s") == "not-a-value-0000").ranges == []
    assert r.prune(col("s") == vals[1234]).ranges  # present value survives
    # substring predicates get no bloom verdict
    assert r.prune(col("s").contains("999")).ranges == [(0, 3000)]


def test_bloom_filter_unit(rnd):
    vals = [f"key{i}" for i in range(500)]
    bf = BloomFilter.from_values(vals)
    assert all(bf.may_contain(v) for v in vals)  # no false negatives, ever
    false_pos = sum(bf.may_contain(f"absent{i}") for i in range(2000))
    assert false_pos < 40  # ~10 bits/key keeps fp rate around 1%
    raw = bf.bits.tobytes()
    bf2 = BloomFilter(bf.n_bits, bf.k, np.frombuffer(raw, np.uint8))
    assert bf2.may_contain("key7") and all(bf2.may_contain(v) for v in vals)


# -- the acceptance matrix ----------------------------------------------------


@pytest.fixture(scope="module")
def crawl(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("crawl-pushdown") / "d")
    records = make_crawl_records(2000)
    w = COFWriter(root, urlinfo_schema(),
                  formats={"metadata": ColumnFormat("dcsl"),
                           "url": ColumnFormat("skiplist"),
                           "srcUrl": ColumnFormat("cblock", codec="zlib"),
                           "content": ColumnFormat("cblock", codec="zlib")},
                  split_records=256)
    w.append_all(records)
    w.close()
    return root, records


T0 = 1300000000

# predicate x target encoding/kind combinations over the crawl dataset:
# fetchTime = sorted plain/delta ints; url = skiplist dict strings;
# srcUrl = cblock strings; metadata = dcsl (late-materialized only)
PREDICATES = [
    ("sorted-int-range", col("fetchTime") < T0 + 120,
     lambda r: r["fetchTime"] < T0 + 120, True),
    ("sorted-int-band", (col("fetchTime") >= T0 + 500) & (col("fetchTime") < T0 + 700),
     lambda r: T0 + 500 <= r["fetchTime"] < T0 + 700, True),
    ("skiplist-string-contains", col("url").contains("ibm.com/jp"),
     lambda r: "ibm.com/jp" in r["url"], False),
    ("string-eq", col("url") == "http://ibm.com/jp/page/77",
     lambda r: r["url"] == "http://ibm.com/jp/page/77", False),
    ("int-isin", col("fetchTime").isin([T0 + 3, T0 + 4, T0 + 1900]),
     lambda r: r["fetchTime"] in (T0 + 3, T0 + 4, T0 + 1900), True),
    ("compound-or", (col("fetchTime") < T0 + 64) | col("url").contains("/jp/"),
     lambda r: r["fetchTime"] < T0 + 64 or "/jp/" in r["url"], False),
    ("negation", ~(col("fetchTime") >= T0 + 256),
     lambda r: not (r["fetchTime"] >= T0 + 256), True),
    ("match-nothing", col("fetchTime") < T0,
     lambda r: False, True),
    ("match-everything", col("fetchTime") >= T0,
     lambda r: True, False),
    # map-key leaves over the dcsl metadata column (PR-5 complex types):
    # equality/contains fetch ONLY the referenced key via lookup_many;
    # an absent key prunes every split from _meta.json key presence alone
    ("map-key-eq", col("metadata")["content-type"] == "text/html",
     lambda r: r["metadata"].get("content-type") == "text/html", False),
    ("map-key-contains", col("metadata")["server"].contains("apache/1"),
     lambda r: "apache/1" in r["metadata"].get("server", ""), False),
    ("map-key-absent", col("metadata")["no-such-key"] == "x",
     lambda r: False, True),
    ("map-key-compound", (col("metadata")["language"] == "jp")
     & (col("fetchTime") < T0 + 1000),
     lambda r: r["metadata"].get("language") == "jp"
     and r["fetchTime"] < T0 + 1000, True),
    # string ordering: vectorized lexicographic compare over RaggedColumn
    # (and its dict/skiplist views), tie-broken on lengths
    ("string-order-range", (col("url") >= "http://ibm.com/jp/page/50")
     & (col("url") < "http://ibm.com/jp/page/70"),
     lambda r: "http://ibm.com/jp/page/50" <= r["url"]
     < "http://ibm.com/jp/page/70", False),
    ("string-order-cblock", col("srcUrl") <= "http://example.org/src/200",
     lambda r: r["srcUrl"] <= "http://example.org/src/200", False),
]


@pytest.mark.parametrize("name,pred,oracle,expect_prune",
                         PREDICATES, ids=[p[0] for p in PREDICATES])
def test_where_scan_bit_identical_to_posthoc_filter(crawl, name, pred, oracle,
                                                    expect_prune):
    root, records = crawl
    columns = ["url", "fetchTime", "srcUrl"]
    expect = [(r["url"], r["fetchTime"], r["srcUrl"])
              for r in records if oracle(r)]

    r_w = CIFReader(root, columns=columns)
    got = []
    for b in r_w.scan_batches(batch_size=100, where=pred):
        got.extend(zip(_as_list(b["url"]), _as_list(b["fetchTime"]),
                       _as_list(b["srcUrl"])))
    assert got == expect
    if expect_prune:  # selective predicates over sorted columns must prune
        assert r_w.stats.blocks_pruned_stats > 0, name
    # pruning + short-circuiting never lose or duplicate a row
    assert r_w.stats.rows_short_circuited >= 0


@pytest.mark.parametrize("name,pred,oracle,expect_prune",
                         PREDICATES[:6], ids=[p[0] for p in PREDICATES[:6]])
def test_where_job_serial_concurrent_identical(crawl, name, pred, oracle,
                                               expect_prune):
    root, records = crawl

    def map_batch(split_id, cols, emit):
        for u, t in zip(cols["url"], _as_list(cols["fetchTime"])):
            emit(None, (u, t))

    runs = []
    for workers in (1, 4):
        r = CIFReader(root, columns=["url", "fetchTime"])
        ids, ob = r.job_inputs(batch_size=100, where=pred)
        res = run_job(ids, n_hosts=4, n_workers=workers,
                      open_split_batches=ob, map_batch_fn=map_batch)
        runs.append((res, r.stats))
    (res1, st1), (res4, st4) = runs
    assert res1.output == res4.output
    assert vars(st1) == vars(st4)  # counters identical serial vs concurrent
    expect = sorted((r["url"], r["fetchTime"]) for r in records if oracle(r))
    got = sorted(v for _, vs in res1.output for v in vs)  # no reducer: grouped
    assert got == expect
    if expect_prune:
        assert st1.blocks_pruned_stats > 0


def test_where_sharded_scan_partitions_exactly(crawl):
    root, records = crawl
    pred = col("fetchTime") < T0 + 900
    expect = sorted(r["url"] for r in records if r["fetchTime"] < T0 + 900)
    got = []
    for host in range(3):
        r = CIFReader(root, columns=["url"])
        for b in r.scan_batches(batch_size=128, where=pred, host=host, n_hosts=3):
            got.extend(b["url"])
    assert sorted(got) == expect


def test_run_job_where_record_mode(crawl):
    root, records = crawl
    pred = col("url").contains("ibm.com/jp")

    def map_rec(key, rec, emit):
        emit(None, rec.get("fetchTime"))

    r = CIFReader(root, columns=["url", "fetchTime"], lazy=True)
    ids, osp = r.job_records()
    res = run_job(ids, osp, map_rec, n_hosts=3, where=pred)
    expect = sorted(x["fetchTime"] for x in records if "ibm.com/jp" in x["url"])
    assert sorted(v for _, vs in res.output for v in vs) == expect


def test_where_late_materializes_only_matching_rows(crawl):
    """The payload column decodes exactly the matching rows — the paper's
    lazy record construction, automatic."""
    root, records = crawl
    pred = col("fetchTime") < T0 + 50
    r = CIFReader(root, columns=["srcUrl"])
    rows = 0
    for b in r.scan_batches(batch_size=100, where=pred):
        rows += len(b["srcUrl"])
    assert rows == 50
    sc = r.stats
    # srcUrl (cblock) decoded only the 50 matches; fetchTime decoded only
    # the surviving block (256-record splits -> 1 stats block survives)
    assert sc.cells_decoded == 50 + 256
    assert sc.blocks_pruned_stats > 0
    assert sc.rows_short_circuited == 256 - 50


def test_mapkey_where_never_decodes_nonmatching_cells(tmp_path):
    """The ISSUE-5 acceptance: a map-key ``where=`` over a DCSL column is
    bit-identical to a post-hoc filtered unpruned scan, and ``ReadCounters``
    prove the non-matching map cells were never decoded — cells in blocks
    without the key are never even visited (presence pruning), and visited
    candidates decode ONLY the referenced key's entry (``lookup_many``), so
    ``bytes_decoded`` stays at the single-entry level, not the map-cell
    level."""
    from repro.core.schema import INT64, MAP, STRING, Schema

    root = str(tmp_path / "d")
    schema = Schema([("i", INT64()), ("attrs", MAP(STRING()))])
    n = 4000
    records = []
    for i in range(n):
        m = {"pad": "x" * 40, "lang": ["en", "jp"][i % 2]}
        if i < 1000:  # key presence clustered in the first DCSL block
            m["hot"] = "yes" if i % 4 == 0 else "no"
        records.append({"i": i, "attrs": m})
    w = COFWriter(root, schema, formats={"attrs": ColumnFormat("dcsl")},
                  split_records=2000)
    w.append_all(records)
    w.close()

    pred = col("attrs")["hot"] == "yes"
    expect = [r["i"] for r in records
              if r["attrs"].get("hot") == "yes"]

    r_w = CIFReader(root, columns=["i"])
    got = []
    for b in r_w.scan_batches(batch_size=512, where=pred):
        got.extend(_as_list(b["i"]))
    assert got == expect  # bit-identical to the post-hoc oracle

    st = r_w.stats
    # split 1 (rows 2000-4000) pruned wholesale from _meta.json key
    # presence; block 1 of split 0 pruned from the v3.1 stats-tag.  Only
    # the 1000 rows of block 0 were candidates:
    assert st.blocks_pruned_stats == 3
    assert st.rows_short_circuited == 1000 - len(expect)
    # attrs: 1000 single-key lookups; i: only the matching rows decode
    assert st.cells_decoded == 1000 + len(expect)
    # the real §6 claim: lookups decode single entries, never whole map
    # cells — an eager scan of just the candidate block costs ~46KB here
    assert st.bytes_decoded < 3000

    # same result through a full unpruned scan + post-hoc filter, which
    # decodes every map cell of every row
    r_full = CIFReader(root, columns=["i", "attrs"])
    got_full = []
    for b in r_full.scan_batches(batch_size=512):
        for i, m in zip(_as_list(b["i"]), b["attrs"]):
            if m.get("hot") == "yes":
                got_full.append(i)
    assert got_full == expect
    assert r_full.stats.cells_decoded == 2 * n  # the cost we avoided


def test_mapkey_predicate_on_projected_map_column(crawl):
    """A predicate map column that is ALSO projected decodes whole cells
    once (the monotone reader cannot serve lookup_many and read_many over
    the same rows) and the filtered span serves them from cache."""
    root, records = crawl
    pred = col("metadata")["language"] == "jp"
    r = CIFReader(root, columns=["url", "metadata"])
    got = []
    for b in r.scan_batches(batch_size=256, where=pred):
        for u, m in zip(_as_list(b["url"]), b["metadata"]):
            assert m["language"] == "jp"
            got.append((u, m["content-type"]))
    expect = [(x["url"], x["metadata"]["content-type"]) for x in records
              if x["metadata"].get("language") == "jp"]
    assert got == expect


def test_mapkey_multiple_keys_one_column(crawl):
    """Two keys of one map column in one predicate: whole cells decode
    once, both keys derive from them, result still bit-identical."""
    root, records = crawl
    pred = (col("metadata")["language"] == "jp") \
        | (col("metadata")["content-type"] == "application/pdf")
    r = CIFReader(root, columns=["fetchTime"])
    got = []
    for b in r.scan_batches(batch_size=300, where=pred):
        got.extend(_as_list(b["fetchTime"]))
    expect = [x["fetchTime"] for x in records
              if x["metadata"].get("language") == "jp"
              or x["metadata"].get("content-type") == "application/pdf"]
    assert got == expect


def test_float32_bounds_widened_for_literal_rounding(tmp_path):
    """float32 cells evaluate against float64 literals at float32
    precision, so zone-map bounds are widened by one float32 ULP — a
    literal that is not the stored bound but ROUNDS to it must not prune
    the rows it matches (where= == post-hoc, the core contract)."""
    from repro.core.schema import FLOAT32, Schema

    root = str(tmp_path / "d")
    w = COFWriter(root, Schema([("x", FLOAT32())]), split_records=64)
    w.append_all([{"x": 0.2} for _ in range(100)])
    w.close()
    for lit in (0.200000002, 0.1999999985, 0.21):
        for pred in (col("x") >= lit, col("x") == lit, col("x") < lit):
            r_w = CIFReader(root, columns=["x"])
            rows = sum(len(b["x"]) for b in r_w.scan_batches(where=pred))
            r_o = CIFReader(root, columns=["x"])
            oracle = sum(
                int(pred.mask(lambda _n, b=b: b["x"], len(b["x"])).sum())
                for b in r_o.scan_batches())
            assert rows == oracle, (lit, repr(pred), rows, oracle)
    # a clearly-out-of-range literal still prunes
    r = CIFReader(root, columns=["x"])
    assert sum(len(b["x"]) for b in r.scan_batches(where=col("x") > 0.5)) == 0
    assert r.stats.blocks_pruned_stats > 0


def test_job_records_where_validates_and_filters(crawl):
    """`job_records(where=)` validates literals against the schema (the
    schema-agnostic run_job(where=) cannot) and filters records on the
    lazy path."""
    root, records = crawl
    r = CIFReader(root, columns=["url", "fetchTime"], lazy=True)
    with pytest.raises(AssertionError, match="literal"):
        r.job_records(where=col("fetchTime") == "13OO")
    ids, osp = r.job_records(where=col("url").contains("ibm.com/jp"))
    res = run_job(ids, osp, lambda k, rec, emit: emit(None, rec.get("fetchTime")),
                  n_hosts=3)
    expect = sorted(x["fetchTime"] for x in records if "ibm.com/jp" in x["url"])
    assert sorted(v for _, vs in res.output for v in vs) == expect


def test_mapkey_validation():
    from repro.core import validate_predicate

    sch = urlinfo_schema()
    with pytest.raises(AssertionError, match="need"):
        validate_predicate(col("url")["k"] == "x", sch.type_of)  # not a map
    with pytest.raises(AssertionError, match="literal"):
        validate_predicate(col("metadata")["k"] == 7, sch.type_of)
    validate_predicate(col("metadata")["k"] == "v", sch.type_of)  # ok


def test_parse_predicate_map_key():
    p = parse_predicate("metadata[content-type] == 'text/html'")
    assert repr(p) == repr(col("metadata")["content-type"] == "text/html")
    q = parse_predicate("annotations[topic] contains t1")
    assert repr(q) == repr(col("annotations")["topic"].contains("t1"))


def test_vectorized_string_order_masks(rnd):
    """Ordering masks over RaggedColumn (incl. dict views) match brute
    force; the tie-break-on-length edge cases are covered explicitly."""
    from repro.core.varcodec import RaggedColumn

    vals = ["", "a", "aa", "ab", "abc", "b", "ba"] + [
        "".join(rnd.choice("abc") for _ in range(rnd.randint(0, 6)))
        for _ in range(400)
    ]
    raw, _ = _build(STRING(), ColumnFormat("plain"), vals)
    r = ColumnFileReader(raw, STRING())
    rc = r.read_range(0, len(vals))
    for pivot in ("", "a", "ab", "abd", "b", "c", "aab"):
        for pred, brute in [
            (col("s") < pivot, [v < pivot for v in vals]),
            (col("s") <= pivot, [v <= pivot for v in vals]),
            (col("s") > pivot, [v > pivot for v in vals]),
            (col("s") >= pivot, [v >= pivot for v in vals]),
        ]:
            np.testing.assert_array_equal(
                pred.mask(lambda _: rc, len(vals)), np.array(brute),
                err_msg=f"{pred!r}")


def test_cblock_stats_tags_prune_without_decompression():
    """v3.1 per-block stats-tags: compressed string blocks prune eq/isin/
    contains with ZERO inflate calls — the pushdown residual the zone maps
    alone could not close (min/max spans everything here)."""
    vals = [f"type-{(i // 256) % 4}" for i in range(2048)]  # clustered
    raw, _ = _build(STRING(), ColumnFormat("cblock", codec="zlib"), vals)
    r = ColumnFileReader(raw, STRING())
    assert r.format_version == "3.2" and r.block_extras is not None
    assert all(e is not None for e in r.block_extras)
    assert r.prune(col("s") == "type-9").ranges == []
    assert r.prune(col("s").contains("ype-9")).ranges == []
    pr = r.prune(col("s").isin(["type-0", "no"]))
    assert pr.blocks_pruned == 6 and len(pr.ranges) == 2
    assert r.counters.blocks_decompressed == 0  # planning inflated nothing
    # high-cardinality blocks degrade to per-block blooms: eq still prunes
    hi = [f"u{i:06d}" for i in range(2048)]
    raw2, _ = _build(STRING(), ColumnFormat("cblock", codec="zlib"), hi)
    r2 = ColumnFileReader(raw2, STRING())
    assert [e[0] for e in r2.block_extras] == ["bloom"] * len(r2.block_extras)
    pr2 = r2.prune(col("s") == "u000300")
    assert pr2.blocks_pruned >= len(r2.block_extras) - 1
    assert any(a <= 300 < b for a, b in pr2.ranges)
    assert r2.counters.blocks_decompressed == 0


def test_v31_footer_ignored_bit_compatibly():
    """The v3.1 trailing sections must be invisible to everything that
    predates them: the header version byte stays 3, the v3 page prefix is
    byte-identical, and unknown future section ids skip cleanly by their
    declared length."""
    from repro.core.stats import (
        StatsCollector, decode_stats_page, encode_stats_page,
    )
    from repro.core.varcodec import write_uvarint

    vals = [f"t{i % 3}" for i in range(1024)]
    raw, w = _build(STRING(), ColumnFormat("cblock", codec="zlib"), vals)
    r = ColumnFileReader(raw, STRING())
    assert r.version == 3 and r.format_version == "3.2"
    assert _as_list(r.read_range(0, 1024)) == vals
    assert [z.count for z in r.block_stats()] == [256] * 4

    # the v3.1 page == the v3 page + trailing sections, byte for byte
    zc = w._zone
    bloom = None
    page_v3 = encode_stats_page(STRING(), zc.zone_maps, bloom)
    page_v31 = encode_stats_page(STRING(), zc.zone_maps, bloom,
                                 zc.block_extras)
    assert page_v31[: len(page_v3)] == page_v3
    # a v3-style parse (zone maps + bloom slot) reads the prefix unchanged
    zms, bf, extras, _ = decode_stats_page(STRING(), page_v3, 0)
    assert extras is None and len(zms) == 4
    # the v3.1 parse finds the per-block stats-tags
    zms2, _, extras2, _ = decode_stats_page(STRING(), page_v31, 0)
    assert [z.count for z in zms2] == [z.count for z in zms]
    assert extras2 is not None and all(e is not None for e in extras2)

    # splice an unknown future section in front: skipped by length, the
    # known section still parses
    known_ext = page_v31[len(page_v3) + 1:]  # sections minus the count byte
    future = bytearray()
    future.append(2)  # n_sections
    future.append(0x7F)  # unknown id
    write_uvarint(future, 5)
    future += b"hello"
    future += known_ext
    _, _, extras3, _ = decode_stats_page(
        STRING(), page_v3 + bytes(future), 0)
    assert extras3 == extras2


def test_filter_requires_opened_predicate_columns(crawl):
    root, _ = crawl
    r = CIFReader(root, columns=["srcUrl"])  # url not opened
    ids, ob = r.job_inputs(batch_size=128)
    with pytest.raises(AssertionError, match="unopened"):
        run_job(ids, reduce_fn=fig1_reduce, n_hosts=2, open_split_batches=ob,
                where=col("url").contains("x"),
                map_batch_fn=lambda s, c, e: None)


def test_double_filtering_rejected(crawl):
    root, _ = crawl
    r = CIFReader(root, columns=["url"])
    ids, ob = r.job_inputs(batch_size=128, where=col("url").contains("jp"))
    with pytest.raises(AssertionError, match="not both"):
        run_job(ids, n_hosts=2, open_split_batches=ob,
                where=col("url").contains("jp"),
                map_batch_fn=lambda s, c, e: None)


# -- fig1: the rewritten blessed path vs the hand-rolled oracle ---------------


def _fig1_map_batch_manual(pattern="ibm.com/jp"):
    """The pre-pushdown hand-rolled implementation (PR 2), kept verbatim as
    the equivalence oracle for the where= rewrite."""

    def map_batch(split_id, cols, emit):
        urls = cols["url"]
        if hasattr(urls, "contains"):
            mask = urls.contains(pattern)
        else:
            mask = np.fromiter((pattern in u for u in urls), bool, count=len(urls))
        rows = np.flatnonzero(mask)
        if not len(rows):
            return
        cts = cols.sparse("metadata", rows, key="content-type")
        for ct in cts:
            if ct is not None:
                emit(None, ct)

    return map_batch


def test_fig1_where_equals_manual_and_record_paths(crawl):
    root, records = crawl
    expect = sorted({r["metadata"]["content-type"] for r in records
                     if "ibm.com/jp" in r["url"]})

    r_rec = CIFReader(root, columns=["url", "metadata"], lazy=True)
    ids, osp = r_rec.job_records()
    rec = run_job(ids, osp, fig1_map(), fig1_reduce, n_hosts=3)

    r_man = CIFReader(root, columns=["url", "metadata"])
    ids_m, ob_m = r_man.job_inputs(batch_size=100)
    manual = run_job(ids_m, reduce_fn=fig1_reduce, n_hosts=3,
                     open_split_batches=ob_m,
                     map_batch_fn=_fig1_map_batch_manual())

    r_new = CIFReader(root, columns=["url", "metadata"])
    ids_n, ob_n = r_new.job_inputs(batch_size=100, where=fig1_where())
    blessed = run_job(ids_n, reduce_fn=fig1_reduce, n_hosts=3,
                      open_split_batches=ob_n, map_batch_fn=fig1_map_batch())

    assert blessed.output == manual.output == rec.output
    assert [v for _, v in blessed.output] == expect
    # unfiltered spans are rejected loudly, not silently unfiltered
    r_bad = CIFReader(root, columns=["url", "metadata"])
    ids_b, ob_b = r_bad.job_inputs(batch_size=100)
    with pytest.raises(AssertionError, match="predicate-filtered"):
        run_job(ids_b, reduce_fn=fig1_reduce, n_hosts=2,
                open_split_batches=ob_b, map_batch_fn=fig1_map_batch())


# -- format compatibility matrix ----------------------------------------------

V1_TYPES = {
    "plain_int64": INT64(), "skiplist_string": STRING(),
    "cblock_zlib_string": STRING(), "dcsl_map": MAP(STRING()),
}
V2_TYPES = {
    "plain_int64": INT64(), "plain_dict_string": STRING(),
    "cblock_zlib_string": STRING(), "skiplist_dict_string": STRING(),
    "dcsl_map": MAP(STRING()),
}


@pytest.mark.parametrize("version,prefix,types,expected_json", [
    (1, "prepr", V1_TYPES, "prepr_expected.json"),
    (2, "v2", V2_TYPES, "v2_expected.json"),
])
def test_old_versions_read_and_plan_scan_everything(version, prefix, types,
                                                    expected_json):
    with open(os.path.join(FIXTURES, expected_json)) as f:
        exp = json.load(f)
    for name, typ in types.items():
        with open(os.path.join(FIXTURES, f"{prefix}_{name}.col"), "rb") as f:
            raw = f.read()
        r = ColumnFileReader(raw, typ)
        assert r.version == version
        assert r.block_stats() is None  # no stats page before v3
        assert _as_list(r.read_range(0, r.n)) == exp[name]
        # scalar access bit-identical too
        r2 = ColumnFileReader(raw, typ)
        assert [r2.value_at(i) for i in range(0, r2.n, 17)] == exp[name][::17]
        # stats-based planning degrades to "scan everything": a range
        # predicate (which only zone maps could decide) prunes nothing
        if typ.kind == "int64":
            pr = ColumnFileReader(raw, typ).prune(col("x") < -10**9)
            assert pr.ranges == [(0, r.n)] and pr.blocks_pruned == 0


def test_v2_dict_pages_still_prune_without_stats():
    """v2 predates zone maps, but dict-encoded blocks carry their value set
    in-band — eq/isin/contains pruning rides the dictionary pages."""
    with open(os.path.join(FIXTURES, "v2_plain_dict_string.col"), "rb") as f:
        raw = f.read()
    r = ColumnFileReader(raw, STRING())
    assert r.version == 2 and r.block_stats() is None
    assert r.prune(col("s") == "absent/type").ranges == []
    pr = r.prune(col("s") == "text/html")
    assert pr.ranges == [(0, r.n)] and pr.blocks_pruned == 0


def test_v3_fixture_reads_with_stats():
    with open(os.path.join(FIXTURES, "v3_expected.json")) as f:
        exp = json.load(f)
    with open(os.path.join(FIXTURES, "v3_plain_int64.col"), "rb") as f:
        ints = f.read()
    r = ColumnFileReader(ints, INT64())
    assert r.version == 3 and r.block_stats()
    assert _as_list(r.read_range(0, r.n)) == exp["plain_int64"]
    pr = r.prune(col("x") < exp["plain_int64"][0] + 1)
    assert pr.blocks_pruned == pr.blocks_total - 1
    with open(os.path.join(FIXTURES, "v3_plain_dict_string.col"), "rb") as f:
        langs = f.read()
    r2 = ColumnFileReader(langs, STRING())
    assert _as_list(r2.read_range(0, r2.n)) == exp["plain_dict_string"]
    # clustered strings: the jp run survives, the rest prunes
    pr2 = ColumnFileReader(langs, STRING()).prune(col("lang") == "jp")
    assert pr2.blocks_pruned > 0
    jp = [i for i, v in enumerate(exp["plain_dict_string"]) if v == "jp"]
    for i in jp:
        assert any(a <= i < b for a, b in pr2.ranges)


def test_v31_fixtures_read_and_prune():
    """Checked-in v3.1 fixtures next to the v1/v2/v3 matrix: values read
    bit-for-bit, the per-block stats-tags parse, cblock pruning needs no
    inflate call, and map-key presence pruning lands on the DICT_BLOCK
    grid.  Regenerating these must keep this test green — that is the
    fixture half of the FORMAT.md drift guard."""
    with open(os.path.join(FIXTURES, "v31_expected.json")) as f:
        exp = json.load(f)
    with open(os.path.join(FIXTURES, "v31_cblock_zlib_string.col"), "rb") as f:
        straw = f.read()
    r = ColumnFileReader(straw, STRING())
    assert r.version == 3 and r.format_version == "3.1"
    assert _as_list(r.read_range(0, r.n)) == exp["cblock_zlib_string"]
    r2 = ColumnFileReader(straw, STRING())
    tags = [e[0] if e else None for e in r2.block_extras]
    assert "values" in tags and "bloom" in tags  # clustered head, random tail
    assert r2.prune(col("s") == "mime/9").ranges == []
    pr = r2.prune(col("s") == "mime/0")
    assert pr.blocks_pruned > 0
    for i, v in enumerate(exp["cblock_zlib_string"]):
        if v == "mime/0":
            assert any(a <= i < b for a, b in pr.ranges), i
    assert r2.counters.blocks_decompressed == 0

    with open(os.path.join(FIXTURES, "v31_dcsl_map.col"), "rb") as f:
        mraw = f.read()
    rm = ColumnFileReader(mraw, MAP(STRING()))
    assert rm.format_version == "3.1"
    assert rm.read_range(0, rm.n) == exp["dcsl_map"]
    rm2 = ColumnFileReader(mraw, MAP(STRING()))
    assert [e[0] for e in rm2.block_extras] == ["keys"] * 3
    pr2 = rm2.prune(col("m")["content-type"] == "text/html")
    assert pr2.ranges == [(0, 1000)]  # key present only in block 0
    assert rm2.prune(col("m")["absent"] == "x").ranges == []
    assert rm2.prune(col("m")["lang"] == "jp").ranges == [(0, rm2.n)]


# -- observability satellites -------------------------------------------------


def test_storage_report_zone_coverage(tmp_path):
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=512)
    w.append_all(make_crawl_records(1024))
    w.close()
    rep = storage_report(root)
    ft = rep["fetchTime"]["zone"]
    assert ft["blocks"] == 2  # one block per split
    assert ft["min"] == T0 and ft["max"] == T0 + 1023
    assert rep["url"]["zone"]["bloom"] is True
    # map columns: key-presence coverage (exact split-level key union)
    md = rep["metadata"]["zone"]
    assert md["blocks"] == 2 and md["min"] is None
    assert md["keys"] == ["content-type", "encoding", "language", "server",
                          "status"]
    # content cells exceed MINMAX_MAX_BYTES: blocks counted, bounds dropped
    assert rep["content"]["zone"]["blocks"] > 0
    assert rep["content"]["zone"]["min"] is None


def test_load_data_where_report(tmp_path, capsys):
    from repro.launch.load_data import synth_crawl_records, where_report

    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=512)
    w.append_all(synth_crawl_records(2048))
    w.close()
    out = where_report(root, f"fetchTime < {T0 + 100}", ["url", "fetchTime"])
    assert out["rows"] == 100
    assert out["blocks_pruned"] > 0
    assert "blocks pruned by stats" in capsys.readouterr().out
