import os
import sys

# smoke tests and benches must see ONE device; only launch/dryrun.py (run as
# a separate process) sets the 512-device flag.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import random

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def rnd():
    return random.Random(0)


def make_crawl_records(n, seed=0, content_bytes=256):
    from repro.launch.load_data import synth_crawl_records

    return list(synth_crawl_records(n, seed=seed, content_bytes=content_bytes))
