"""Distribution tests.

Multi-device behaviour runs in a SUBPROCESS (tests must see 1 device; jax
locks the device count at first init).  Sharding-spec logic is tested
in-process since it is pure metadata.
"""
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import SHAPES, get_config, reduced
from repro.models import lm
from repro.models.spec import LeafSpec, leaf_pspec


def test_multidevice_subprocess():
    script = os.path.join(os.path.dirname(__file__), "_distributed_check.py")
    r = subprocess.run(
        [sys.executable, script], capture_output=True, text=True, timeout=1500
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "DISTRIBUTED CHECKS PASSED" in r.stdout


def test_leaf_pspec_divisibility_fallback():
    sizes = {"data": 16, "model": 16}
    rules = {"kv_heads": "model", "mlp": "model", "embed": None}
    # kv dim 8 not divisible by 16 -> replicated; mlp 5632 divisible -> sharded
    l = LeafSpec((2048, 8 * 64), ("embed", "kv_heads"))
    assert leaf_pspec(l, rules, sizes)[1] is None or leaf_pspec(l, rules, sizes) is not None
    l2 = LeafSpec((2048, 5632), ("embed", "mlp"))
    ps = leaf_pspec(l2, rules, sizes)
    assert tuple(ps) == (None, "model")


def test_pspec_never_reuses_axis():
    sizes = {"data": 16, "model": 16}
    rules = {"experts": "model", "mlp": ("model", "data"), "embed": None}
    l = LeafSpec((16, 6144, 10752), ("experts", "embed", "mlp"))
    ps = leaf_pspec(l, rules, sizes)
    flat = []
    for p in ps:
        if p is None:
            continue
        flat.extend([p] if isinstance(p, str) else list(p))
    assert len(flat) == len(set(flat)), ps


def test_input_specs_cover_all_cells():
    """Every non-skipped (arch x shape) cell must produce valid input specs."""
    from repro.configs import all_configs

    n = 0
    for arch, cfg in all_configs().items():
        for sname, shape in SHAPES.items():
            if cfg.skip_reason(sname):
                continue
            specs = lm.input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves, (arch, sname)
            n += 1
    assert n == 32, n  # 40 nominal - 8 skips


def test_skip_matrix_documented():
    skips = []
    from repro.configs import all_configs

    for arch, cfg in all_configs().items():
        for sname in SHAPES:
            r = cfg.skip_reason(sname)
            if r:
                skips.append((arch, sname, r))
    assert len(skips) == 8
    assert ("hubert-xlarge", "decode_32k",
            "encoder-only arch has no decode step") in skips
