"""Storage engine behaviour: every format kind, laziness, skip lists,
compression, schema evolution, placement — the paper's §4-§5 machinery."""
import os

import pytest

from repro.core import (
    ARRAY, BYTES, CIFReader, COFWriter, ColumnFileReader, ColumnFileWriter,
    ColumnFormat, FLOAT32, INT32, INT64, MAP, STRING, Placement, Schema,
    WorkQueue, add_column, urlinfo_schema,
)
from repro.core.colfile import CBLOCK_RECORDS
from repro.core.dcsl import DICT_BLOCK
from repro.core.rowgroup import RCFileReader, RCFileWriter
from repro.core.seqfile import SeqReader, write_seq
from repro.core.textfile import TextReader, write_text
from conftest import make_crawl_records

KINDS = [
    ColumnFormat("plain"),
    ColumnFormat("skiplist"),
    ColumnFormat("cblock", codec="lzo"),
    ColumnFormat("cblock", codec="zlib"),
]


@pytest.mark.parametrize("fmt", KINDS, ids=lambda f: f"{f.kind}-{f.codec}")
def test_column_file_roundtrip_map(fmt, rnd):
    typ = MAP(INT32())
    vals = [
        {f"k{rnd.randint(0, 20)}": rnd.randint(-1000, 1000) for _ in range(rnd.randint(0, 8))}
        for _ in range(2500)
    ]
    w = ColumnFileWriter(typ, fmt)
    for v in vals:
        w.append(v)
    r = ColumnFileReader(w.finish(), typ)
    assert [r.value_at(i) for i in range(len(vals))] == vals


def test_dcsl_roundtrip_and_lookup(rnd):
    typ = MAP(STRING())
    vals = [
        {f"key{rnd.randint(0, 15)}": f"v{rnd.randint(0, 99)}" for _ in range(5)}
        for _ in range(3 * DICT_BLOCK + 17)  # multiple dictionary blocks
    ]
    w = ColumnFileWriter(typ, ColumnFormat("dcsl"))
    for v in vals:
        w.append(v)
    raw = w.finish()
    r = ColumnFileReader(raw, typ)
    assert [r.value_at(i) for i in range(len(vals))] == vals
    # single-key lookup decodes only the requested entry, across dict blocks
    r2 = ColumnFileReader(raw, typ)
    for i in range(0, len(vals), 97):
        key = sorted(vals[i])[0]
        assert r2.lookup(i, key) == vals[i][key]
    assert r2.lookup(len(vals) - 1, "missing-key") is None


def test_skiplist_jumps_skip_work(rnd):
    typ = STRING()
    vals = [("x" * 50) + str(i) for i in range(5000)]
    w = ColumnFileWriter(typ, ColumnFormat("skiplist"))
    for v in vals:
        w.append(v)
    raw = w.finish()
    # sparse access: big jumps should touch far less than the full file
    r = ColumnFileReader(raw, typ)
    for i in range(0, 5000, 1000):
        assert r.value_at(i) == vals[i]
    sparse_touched = r.counters.bytes_touched
    r2 = ColumnFileReader(raw, typ)
    for i in range(5000):
        assert r2.value_at(i) == vals[i]
    dense_touched = r2.counters.bytes_touched
    assert sparse_touched < dense_touched / 20, (sparse_touched, dense_touched)


def test_cblock_lazy_decompression(rnd):
    typ = BYTES()
    vals = [bytes([i % 251]) * 300 for i in range(CBLOCK_RECORDS * 8)]
    w = ColumnFileWriter(typ, ColumnFormat("cblock", codec="zlib"))
    for v in vals:
        w.append(v)
    r = ColumnFileReader(w.finish(), typ)
    # touch one value per 2 blocks -> half the blocks stay compressed
    for i in range(0, len(vals), CBLOCK_RECORDS * 2):
        assert r.value_at(i) == vals[i]
    assert r.counters.blocks_decompressed == 4
    assert r.counters.blocks_skipped >= 3


def test_cif_projection_pushdown(tmp_path):
    records = make_crawl_records(300)
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=128)
    w.append_all(records)
    w.close()
    r = CIFReader(root, columns=["url"])
    urls = [rec.get("url") for rec in r.scan()]
    assert urls == [x["url"] for x in records]
    # only url.col opened (3 splits x 1 file)
    assert r.stats.files_opened == 3
    full = CIFReader(root)
    list(full.scan())
    assert full.stats.bytes_io > 3 * r.stats.bytes_io


def test_lazy_record_skips_decode(tmp_path):
    records = make_crawl_records(400)
    root = str(tmp_path / "d")
    w = COFWriter(
        root, urlinfo_schema(),
        formats={"metadata": ColumnFormat("skiplist")},
        split_records=400,
    )
    w.append_all(records)
    w.close()
    r = CIFReader(root, columns=["url", "metadata"], lazy=True)
    hits = 0
    for rec in r.scan():
        if "ibm.com/jp" in rec.get("url"):
            rec.get("metadata")
            hits += 1
    # url decoded for all records, metadata ONLY for matches
    assert r.stats.cells_decoded == 400 + hits
    assert hits < 100  # ~6% selectivity


def test_lazy_record_memoizes_repeated_get(tmp_path):
    records = make_crawl_records(50)
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=50)
    w.append_all(records)
    w.close()
    r = CIFReader(root, columns=["url"], lazy=True)
    for rec in r.scan():
        assert rec.get("url") == rec.get("url")


def test_eager_equals_lazy(tmp_path):
    records = make_crawl_records(200)
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=64)
    w.append_all(records)
    w.close()
    lazy = [
        {n: rec.get(n) for n in urlinfo_schema().names()}
        for rec in CIFReader(root, lazy=True).scan()
    ]
    eager = [
        {n: rec.get(n) for n in urlinfo_schema().names()}
        for rec in CIFReader(root, lazy=False).scan()
    ]
    assert lazy == eager == records


def test_add_column_cheap_schema_evolution(tmp_path):
    records = make_crawl_records(100)
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=40)
    w.append_all(records)
    w.close()
    sizes_before = {
        s: os.path.getsize(os.path.join(d, "content.col"))
        for s, d in CIFReader(root).splits()
    }
    add_column(root, "pagerank", FLOAT32(), lambda si, n: [float(si)] * n)
    r = CIFReader(root, columns=["pagerank"])
    vals = [rec.get("pagerank") for rec in r.scan()]
    assert len(vals) == 100
    # existing column files were NOT rewritten (CIF's win over RCFile, §4.3)
    for s, d in CIFReader(root).splits():
        assert os.path.getsize(os.path.join(d, "content.col")) == sizes_before[s]


@pytest.mark.parametrize("mode", ["plain", "record", "block"])
def test_seq_roundtrip(tmp_path, mode):
    records = make_crawl_records(120)
    p = str(tmp_path / "f.seq")
    write_seq(p, urlinfo_schema(), records, mode=mode)
    assert list(SeqReader(p).scan()) == records


def test_text_roundtrip(tmp_path):
    records = make_crawl_records(60)
    p = str(tmp_path / "f.jsonl")
    write_text(p, urlinfo_schema(), records)
    assert list(TextReader(p, urlinfo_schema()).scan()) == records


@pytest.mark.parametrize("codec", ["none", "zlib"])
def test_rcfile_roundtrip_and_projection(tmp_path, codec):
    records = make_crawl_records(200)
    p = str(tmp_path / "f.rc")
    w = RCFileWriter(p, urlinfo_schema(), rowgroup_bytes=64 * 1024, codec=codec)
    for r in records:
        w.append(r)
    w.close()
    assert list(RCFileReader(p).scan()) == records
    rr = RCFileReader(p, columns=["url"])
    assert [x["url"] for x in rr.scan()] == [x["url"] for x in records]
    assert rr.stats.bytes_io <= os.path.getsize(p) + rr.io_unit


def test_placement_invariants():
    p = Placement(n_splits=97, n_hosts=13, replication=3)
    loads = [0] * 13
    for s in range(97):
        reps = p.replicas(s)
        assert len(set(reps)) == 3  # distinct hosts
        loads[p.primary(s)] += 1
    assert max(loads) - min(loads) <= 1  # round-robin balanced
    # determinism
    assert [p.replicas(s) for s in range(97)] == [p.replicas(s) for s in range(97)]


def test_workqueue_handles_dead_hosts():
    p = Placement(n_splits=40, n_hosts=8, replication=3)
    dead = {2, 5}
    wq = WorkQueue(p, dead_hosts=dead)
    assert wq.coverage_possible()
    live = [h for h in range(8) if h not in dead]
    while not wq.all_done():
        progressed = False
        for h in live:
            s = wq.next_split(h)
            if s is not None:
                assert p.is_local(s, h)  # CPP invariant: never a remote read
                wq.complete(s)
                progressed = True
        assert progressed
    assert len(wq.done) == 40
