"""Serving engine: decode correctness vs reference, continuous batching,
slot reuse hygiene, and batched columnar prompt fetch (PromptStore)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.models.spec import init_params
from repro.serving.engine import PromptStore, Request, ServeEngine


def _engine(arch="tinyllama-1.1b", slots=3, seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(seed))
    return cfg, params, ServeEngine(cfg, params, max_batch=slots, max_seq=64, **kw)


def _reference_decode(cfg, params, prompt, n_new):
    """Single-request greedy decode via raw decode_step calls."""
    caches = lm.init_cache(cfg, 1, 64)
    toks = list(prompt)
    out = []
    step = jax.jit(lambda p, c, t, q: lm.decode_step(p, c, t, q, cfg))
    pos = 0
    for t in toks:
        logits, caches = step(params, caches,
                              jnp.asarray([[t]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        logits, caches = step(params, caches,
                              jnp.asarray([[nxt]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    return out


def test_engine_matches_reference():
    cfg, params, eng = _engine()
    prompt = [5, 9, 2]
    want = _reference_decode(cfg, params, prompt, 6)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    done = eng.run()
    assert done[0].out == want


def test_batching_does_not_change_outputs():
    cfg, params, eng = _engine(slots=4)
    prompts = [[1, 2, 3], [7, 7], [4, 5, 6, 8], [9]]
    singles = [_reference_decode(cfg, params, p, 5) for p in prompts]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=5))
    done = {r.rid: r.out for r in eng.run()}
    for i, want in enumerate(singles):
        assert done[i] == want, i


def test_slot_reuse_is_clean():
    """More requests than slots: a reused slot must not leak prior state."""
    cfg, params, eng = _engine(slots=2)
    ref = _reference_decode(cfg, params, [3, 1, 4], 5)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[3, 1, 4], max_new=5))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert r.out == ref, r.rid


def test_eos_stops_early():
    cfg, params, eng = _engine()
    want = _reference_decode(cfg, params, [2, 3], 8)
    eos = want[2]
    eng.submit(Request(rid=0, prompt=[2, 3], max_new=8, eos=eos))
    done = eng.run()
    assert done[0].out == want[:3]


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m"])
def test_recurrent_arch_slot_reuse(arch):
    cfg, params, eng = _engine(arch, slots=2)
    ref = _reference_decode(cfg, params, [3, 1], 4)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[3, 1], max_new=4))
    for r in eng.run():
        assert r.out == ref, (arch, r.rid)


# -- batched columnar feature fetch ------------------------------------------


@pytest.fixture(scope="module")
def small_corpus(tmp_path_factory):
    from repro.data.tokens import TokenCorpus, TokenCorpusWriter
    from repro.launch.load_data import synth_token_docs

    root = str(tmp_path_factory.mktemp("serve-corpus"))
    w = TokenCorpusWriter(root, seq_len=32, split_records=16)
    for toks, meta in synth_token_docs(40, vocab=120, seed=3):
        w.add_document(toks % 50 + 1, meta)  # small ids, vocab-safe prompts
    w.close()
    return TokenCorpus(root)


def test_prompt_store_batched_fetch_matches_scalar(small_corpus):
    """PromptStore.fetch (one read_batch per split) == per-record record()."""
    store = PromptStore(small_corpus, max_prompt=5)
    refs = [(0, 3), (1, 7), (0, 9), (1, 2), (0, 3)]
    got = store.fetch(refs)
    for (sid, rid), prompt in zip(refs, got):
        toks, mask = small_corpus.open_split(sid).record(rid)
        n = min(int(mask.sum()), 5)
        assert prompt == [int(t) for t in toks[: max(n, 1)]]


def test_engine_prompt_refs_match_inline_prompts(small_corpus):
    """Requests by (split, record) ref decode identically to the same
    prompts submitted inline — the fetch path changes nothing downstream."""
    store = PromptStore(small_corpus, max_prompt=4)
    refs = [(0, 1), (1, 5), (0, 8), (1, 11), (0, 14)]
    prompts = store.fetch(refs)

    cfg, params, eng_ref = _engine(slots=2, prompt_store=store)
    for rid, ref in enumerate(refs):
        eng_ref.submit(Request(rid=rid, prompt_ref=ref, max_new=4))
    by_ref = {r.rid: r.out for r in eng_ref.run()}

    _, _, eng_inline = _engine(slots=2)
    for rid, p in enumerate(prompts):
        eng_inline.submit(Request(rid=rid, prompt=list(p), max_new=4))
    by_inline = {r.rid: r.out for r in eng_inline.run()}
    assert by_ref == by_inline and len(by_ref) == len(refs)


# -- PR 8: shared hot-block cache, prefetch, multi-tenant admission ----------


CACHE_FIELDS = ("cache_hits", "cache_misses", "cache_evictions",
                "bytes_served_from_cache")


def test_prompt_store_reopen_serves_cache_hits(small_corpus):
    """Forward-only reopen of a hot split decodes ~zero bytes: the dict
    page and mask blocks come back from the shared cache."""
    from repro.core.blockcache import BlockCache

    cache = BlockCache(1 << 30)
    store = PromptStore(small_corpus, max_prompt=5, cache=cache)
    refs = [(0, 3), (0, 7)]
    first = store.fetch(refs)
    # readers are now past record 3 -> the same refs force a reopen
    second = store.fetch(refs)
    assert second == first
    assert cache.hits > 0
    sp = store._open[0]  # the reopened split
    decoded = sum(r.counters.bytes_decoded for r in sp.reader.readers.values())
    served = sum(r.counters.bytes_served_from_cache
                 for r in sp.reader.readers.values())
    assert decoded == 0 and served > 0  # second fetch decoded NOTHING
    stats = store.close()
    assert stats.cache_hits == cache.hits
    assert stats.bytes_served_from_cache == cache.bytes_served


def test_serving_outputs_and_stats_identical_cache_on_vs_off(small_corpus):
    """Same request stream with and without the cache: per-rid outputs are
    bit-identical, every PR 1-7 counter except bytes_decoded (and the
    decompression hits avoid) matches, and the bytes_decoded drop equals
    bytes_served_from_cache exactly."""
    from repro.core.blockcache import BlockCache

    refs = [(0, 1), (1, 5), (0, 8), (1, 11), (0, 3), (1, 2), (0, 14), (0, 1)]
    outs, stats = [], []
    for cache in (None, BlockCache(1 << 30)):
        store = PromptStore(small_corpus, max_prompt=4, cache=cache)
        _, _, eng = _engine(slots=2, prompt_store=store)
        for rid, ref in enumerate(refs):
            eng.submit(Request(rid=rid, prompt_ref=ref, max_new=3))
        outs.append({r.rid: r.out for r in eng.run()})
        stats.append(vars(store.close()))
    assert outs[0] == outs[1] and len(outs[0]) == len(refs)
    off, on = stats
    for k in off:
        if k in CACHE_FIELDS or k in ("bytes_decoded", "blocks_decompressed"):
            continue
        assert on[k] == off[k], k
    assert off["bytes_decoded"] == on["bytes_decoded"] + on["bytes_served_from_cache"]
    assert on["cache_hits"] > 0  # repeated splits actually reused blocks


def test_prefetch_outputs_match_sync(small_corpus):
    """Async prefetch changes scheduling, never results."""
    from repro.core.blockcache import BlockCache

    refs = [(0, 1), (1, 5), (0, 8), (1, 11), (0, 14), (1, 7), (0, 3)]
    outs = []
    for prefetch in (False, True):
        store = PromptStore(small_corpus, max_prompt=4,
                            cache=BlockCache(1 << 30))
        _, _, eng = _engine(slots=2, prompt_store=store, prefetch=prefetch)
        for rid, ref in enumerate(refs):
            eng.submit(Request(rid=rid, prompt_ref=ref, max_new=3))
        outs.append({r.rid: r.out for r in eng.run()})
        assert eng.admit_stall_s >= 0.0
        eng.close()
    assert outs[0] == outs[1] and len(outs[0]) == len(refs)


def test_admission_rejects_at_queue_depth():
    from repro.serving.engine import AdmissionPolicy, AdmissionRejected

    _, _, eng = _engine(slots=1, admission=AdmissionPolicy(max_queue_depth=2))
    eng.submit(Request(rid=0, prompt=[1], max_new=2, tenant="a"))
    eng.submit(Request(rid=1, prompt=[1], max_new=2, tenant="a"))
    with pytest.raises(AdmissionRejected) as ei:
        eng.submit(Request(rid=2, prompt=[1], max_new=2, tenant="a"))
    assert ei.value.tenant == "a" and ei.value.limit == 2
    eng.submit(Request(rid=3, prompt=[1], max_new=2, tenant="b"))  # b has room
    assert eng.tenant_stats["a"].rejected == 1
    done = eng.run()
    assert {r.rid for r in done} == {0, 1, 3}


def test_fair_share_admission_interleaves_tenants():
    _, _, eng = _engine(slots=2)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[1], max_new=2, tenant="a"))
    for rid in range(4, 6):
        eng.submit(Request(rid=rid, prompt=[1], max_new=2, tenant="b"))
    # round-robin one per tenant per cycle, deterministic
    order = [r.rid for r in eng._admission_order(6)]
    assert order == [0, 4, 1, 5, 2, 3]
    done = eng.run()
    assert len(done) == 6
    a, b = eng.tenant_stats["a"], eng.tenant_stats["b"]
    assert a.admitted == 4 and b.admitted == 2
    assert a.finished == 4 and b.finished == 2
    assert len(a.latencies_s) == 4 and len(b.latencies_s) == 2
    assert a.peak_queue_depth == 4 and b.peak_queue_depth == 2


def test_cache_watermark_defers_but_never_starves(small_corpus):
    """A saturated cache defers admission while slots are busy, yet every
    request still completes (an idle engine always admits)."""
    from repro.core.blockcache import BlockCache
    from repro.serving.engine import AdmissionPolicy

    store = PromptStore(small_corpus, max_prompt=4, cache=BlockCache(1 << 30))
    _, _, eng = _engine(
        slots=2, prompt_store=store,
        admission=AdmissionPolicy(cache_watermark=0.0),
    )
    # staggered lengths: one slot frees while the other still decodes, so
    # the third request sees a busy engine + saturated cache -> deferred
    for rid, max_new in enumerate((2, 8, 3)):
        eng.submit(Request(rid=rid, prompt_ref=(0, 1 + rid), max_new=max_new))
    done = eng.run()
    assert len(done) == 3
    assert eng.admissions_deferred > 0
