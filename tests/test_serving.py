"""Serving engine: decode correctness vs reference, continuous batching,
slot reuse hygiene, and batched columnar prompt fetch (PromptStore)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.models.spec import init_params
from repro.serving.engine import PromptStore, Request, ServeEngine


def _engine(arch="tinyllama-1.1b", slots=3, seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(seed))
    return cfg, params, ServeEngine(cfg, params, max_batch=slots, max_seq=64, **kw)


def _reference_decode(cfg, params, prompt, n_new):
    """Single-request greedy decode via raw decode_step calls."""
    caches = lm.init_cache(cfg, 1, 64)
    toks = list(prompt)
    out = []
    step = jax.jit(lambda p, c, t, q: lm.decode_step(p, c, t, q, cfg))
    pos = 0
    for t in toks:
        logits, caches = step(params, caches,
                              jnp.asarray([[t]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        logits, caches = step(params, caches,
                              jnp.asarray([[nxt]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    return out


def test_engine_matches_reference():
    cfg, params, eng = _engine()
    prompt = [5, 9, 2]
    want = _reference_decode(cfg, params, prompt, 6)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    done = eng.run()
    assert done[0].out == want


def test_batching_does_not_change_outputs():
    cfg, params, eng = _engine(slots=4)
    prompts = [[1, 2, 3], [7, 7], [4, 5, 6, 8], [9]]
    singles = [_reference_decode(cfg, params, p, 5) for p in prompts]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=5))
    done = {r.rid: r.out for r in eng.run()}
    for i, want in enumerate(singles):
        assert done[i] == want, i


def test_slot_reuse_is_clean():
    """More requests than slots: a reused slot must not leak prior state."""
    cfg, params, eng = _engine(slots=2)
    ref = _reference_decode(cfg, params, [3, 1, 4], 5)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[3, 1, 4], max_new=5))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert r.out == ref, r.rid


def test_eos_stops_early():
    cfg, params, eng = _engine()
    want = _reference_decode(cfg, params, [2, 3], 8)
    eos = want[2]
    eng.submit(Request(rid=0, prompt=[2, 3], max_new=8, eos=eos))
    done = eng.run()
    assert done[0].out == want[:3]


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m"])
def test_recurrent_arch_slot_reuse(arch):
    cfg, params, eng = _engine(arch, slots=2)
    ref = _reference_decode(cfg, params, [3, 1], 4)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[3, 1], max_new=4))
    for r in eng.run():
        assert r.out == ref, (arch, r.rid)


# -- batched columnar feature fetch ------------------------------------------


@pytest.fixture(scope="module")
def small_corpus(tmp_path_factory):
    from repro.data.tokens import TokenCorpus, TokenCorpusWriter
    from repro.launch.load_data import synth_token_docs

    root = str(tmp_path_factory.mktemp("serve-corpus"))
    w = TokenCorpusWriter(root, seq_len=32, split_records=16)
    for toks, meta in synth_token_docs(40, vocab=120, seed=3):
        w.add_document(toks % 50 + 1, meta)  # small ids, vocab-safe prompts
    w.close()
    return TokenCorpus(root)


def test_prompt_store_batched_fetch_matches_scalar(small_corpus):
    """PromptStore.fetch (one read_batch per split) == per-record record()."""
    store = PromptStore(small_corpus, max_prompt=5)
    refs = [(0, 3), (1, 7), (0, 9), (1, 2), (0, 3)]
    got = store.fetch(refs)
    for (sid, rid), prompt in zip(refs, got):
        toks, mask = small_corpus.open_split(sid).record(rid)
        n = min(int(mask.sum()), 5)
        assert prompt == [int(t) for t in toks[: max(n, 1)]]


def test_engine_prompt_refs_match_inline_prompts(small_corpus):
    """Requests by (split, record) ref decode identically to the same
    prompts submitted inline — the fetch path changes nothing downstream."""
    store = PromptStore(small_corpus, max_prompt=4)
    refs = [(0, 1), (1, 5), (0, 8), (1, 11), (0, 14)]
    prompts = store.fetch(refs)

    cfg, params, eng_ref = _engine(slots=2, prompt_store=store)
    for rid, ref in enumerate(refs):
        eng_ref.submit(Request(rid=rid, prompt_ref=ref, max_new=4))
    by_ref = {r.rid: r.out for r in eng_ref.run()}

    _, _, eng_inline = _engine(slots=2)
    for rid, p in enumerate(prompts):
        eng_inline.submit(Request(rid=rid, prompt=list(p), max_new=4))
    by_inline = {r.rid: r.out for r in eng_inline.run()}
    assert by_ref == by_inline and len(by_ref) == len(refs)
