"""Serving engine: decode correctness vs reference, continuous batching,
slot reuse hygiene."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.models.spec import init_params
from repro.serving.engine import Request, ServeEngine


def _engine(arch="tinyllama-1.1b", slots=3, seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(seed))
    return cfg, params, ServeEngine(cfg, params, max_batch=slots, max_seq=64, **kw)


def _reference_decode(cfg, params, prompt, n_new):
    """Single-request greedy decode via raw decode_step calls."""
    caches = lm.init_cache(cfg, 1, 64)
    toks = list(prompt)
    out = []
    step = jax.jit(lambda p, c, t, q: lm.decode_step(p, c, t, q, cfg))
    pos = 0
    for t in toks:
        logits, caches = step(params, caches,
                              jnp.asarray([[t]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        logits, caches = step(params, caches,
                              jnp.asarray([[nxt]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
        pos += 1
    return out


def test_engine_matches_reference():
    cfg, params, eng = _engine()
    prompt = [5, 9, 2]
    want = _reference_decode(cfg, params, prompt, 6)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    done = eng.run()
    assert done[0].out == want


def test_batching_does_not_change_outputs():
    cfg, params, eng = _engine(slots=4)
    prompts = [[1, 2, 3], [7, 7], [4, 5, 6, 8], [9]]
    singles = [_reference_decode(cfg, params, p, 5) for p in prompts]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=5))
    done = {r.rid: r.out for r in eng.run()}
    for i, want in enumerate(singles):
        assert done[i] == want, i


def test_slot_reuse_is_clean():
    """More requests than slots: a reused slot must not leak prior state."""
    cfg, params, eng = _engine(slots=2)
    ref = _reference_decode(cfg, params, [3, 1, 4], 5)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[3, 1, 4], max_new=5))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert r.out == ref, r.rid


def test_eos_stops_early():
    cfg, params, eng = _engine()
    want = _reference_decode(cfg, params, [2, 3], 8)
    eos = want[2]
    eng.submit(Request(rid=0, prompt=[2, 3], max_new=8, eos=eos))
    done = eng.run()
    assert done[0].out == want[:3]


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m"])
def test_recurrent_arch_slot_reuse(arch):
    cfg, params, eng = _engine(arch, slots=2)
    ref = _reference_decode(cfg, params, [3, 1], 4)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[3, 1], max_new=4))
    for r in eng.run():
        assert r.out == ref, (arch, r.rid)
