"""Subprocess helper for test_distributed.py: runs under 8 fake devices.

Checks (on a mini (pod=2, data=2, model=2) mesh with the SAME sharding code
the production mesh uses):
  1. train/prefill/decode steps lower+compile AND execute with real arrays
  2. losses are finite; sharded state round-trips
  3. compressed_pod_mean ~= exact mean (int8 + error feedback)
  4. multi-pod lowering contains cross-pod collectives
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import default_sharding, named
from repro.distributed.steps import (
    StepOptions, build_decode_step, build_prefill_step, build_train_step,
    init_state,
)
from repro.models import lm
from repro.models.spec import init_params


def mini_mesh():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


def check_steps(arch: str) -> None:
    cfg = dataclasses.replace(reduced(get_config(arch)), remat="block")
    mesh = mini_mesh()
    sh = default_sharding(cfg)
    shape = ShapeConfig("t", 64 if cfg.frontend != "vision" else 64, 8, "train")
    rng = np.random.default_rng(0)
    with mesh:
        step, (sp, bp) = build_train_step(cfg, sh, mesh, shape, StepOptions())
        state = jax.device_put(init_state(cfg, jax.random.PRNGKey(0)), named(sp, mesh))
        specs = lm.input_specs(cfg, shape)

        def concrete(t, name):
            if t.dtype == jnp.int32:
                hi = cfg.vocab_size if name in ("tokens", "labels") else 2
                return jnp.asarray(rng.integers(0, hi, t.shape), jnp.int32)
            return jnp.asarray(rng.normal(size=t.shape) * 0.1, t.dtype)

        batch = {k: concrete(v, k) for k, v in specs.items()}
        batch = jax.device_put(batch, named(bp, mesh))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), (arch, loss)
        print(f"  {arch}: train_step ok, loss={loss:.3f}")

        if cfg.supports_decode:
            dshape = ShapeConfig("d", 64, 8, "decode")
            dstep, _ = build_decode_step(cfg, sh, mesh, dshape, StepOptions())
            ins = lm.input_specs(cfg, dshape)
            caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), ins["caches"])
            toks = jnp.ones((8, 1), jnp.int32)
            pos = jnp.zeros((8,), jnp.int32)
            logits, caches = dstep(state["params"], caches, toks, pos)
            assert np.isfinite(np.asarray(logits, np.float32)).all()
            print(f"  {arch}: decode_step ok")


def check_compression() -> None:
    from repro.training.compression import compressed_pod_mean, init_error

    mesh = mini_mesh()
    rng = np.random.default_rng(1)
    # stacked per-pod partial grads (dim0 = pod)
    g = {"w": jnp.asarray(rng.normal(size=(2, 512)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(2, 33)), jnp.float32)}
    err = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    with mesh:
        mean, new_err = compressed_pod_mean(g, err, mesh, axis="pod")
    for k in g:
        want = np.mean(np.asarray(g[k]), axis=0)
        got = np.asarray(mean[k])
        scale = np.abs(np.asarray(g[k])).max() / 127
        assert np.abs(got - want).max() <= 2 * scale, k
    print("  compressed_pod_mean ok (within quantization bound)")


def check_pod_collectives() -> None:
    """Multi-pod lowering must shard the pod axis (cross-pod collectives)."""
    cfg = reduced(get_config("tinyllama-1.1b"))
    mesh = mini_mesh()
    sh = default_sharding(cfg)
    shape = ShapeConfig("t", 64, 8, "train")
    from repro.distributed.steps import abstract_state

    with mesh:
        step, _ = build_train_step(cfg, sh, mesh, shape, StepOptions())
        txt = step.lower(abstract_state(cfg), lm.input_specs(cfg, shape)).compile().as_text()
    assert "all-reduce" in txt
    print("  pod-axis collectives present in HLO")


def check_moe_ep_shardmap() -> None:
    """shard_map EP MoE == GSPMD capacity path, and differentiable."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import make_constrain
    from repro.models import moe as M
    from repro.models.spec import init_params as ip

    mesh = mini_mesh()
    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")), dtype="float32")
    sh = default_sharding(cfg)
    rules = dict(sh.rules)
    rules["experts"] = "model"
    rules["mlp"] = None
    sh = sh.with_(rules=rules)
    constrain = make_constrain(sh, mesh)
    p = ip(M.moe_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)
    cfg_ep = dataclasses.replace(cfg, moe_impl="capacity_ep")
    with mesh:
        y_ref, _ = M.moe_apply_capacity(p, x, cfg, capacity_factor=1.25)
        xd = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None, None)))
        y_ep, _ = jax.jit(lambda p_, x_: M.moe_apply(p_, x_, cfg_ep, constrain=constrain))(p, xd)
        g = jax.grad(
            lambda p_: jnp.sum(M.moe_apply(p_, xd, cfg_ep, constrain=constrain)[0])
        )(p)
    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    assert err < 1e-4, err
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print(f"  moe capacity_ep shard_map ok (err={err:.1e})")


def check_pipeline_parallelism() -> None:
    from repro.distributed.pipeline_par import (
        mlp_stage, pipeline_apply, pp_dryrun, pp_reference,
    )

    mesh = jax.make_mesh((4, 2), ("stage", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    S, M, mb, d = 4, 6, 4, 32
    params = {"w1": jnp.asarray(rng.normal(size=(S, d, 4 * d)) * 0.05, jnp.float32),
              "w2": jnp.asarray(rng.normal(size=(S, 4 * d, d)) * 0.05, jnp.float32)}
    xs = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)
    with mesh:
        y = pipeline_apply(params, xs, mlp_stage, mesh, S)
        g = jax.grad(lambda p: jnp.mean(jnp.square(
            pipeline_apply(p, xs, mlp_stage, mesh, S))))(params)
    ref = pp_reference(params, xs, mlp_stage, S)
    gr = jax.grad(lambda p: jnp.mean(jnp.square(pp_reference(p, xs, mlp_stage, S))))(params)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4
    assert max(float(jnp.max(jnp.abs(g[k] - gr[k]))) for k in g) < 1e-5
    r = pp_dryrun()
    assert r["compiled"] and r["collective_permutes"] >= 1
    print(f"  pipeline parallelism ok (GPipe schedule, {r['collective_permutes']} permutes in HLO)")


if __name__ == "__main__":
    assert len(jax.devices()) == 8, jax.devices()
    for arch in ("tinyllama-1.1b", "olmoe-1b-7b", "zamba2-1.2b"):
        check_steps(arch)
    check_compression()
    check_pod_collectives()
    check_moe_ep_shardmap()
    check_pipeline_parallelism()
    print("DISTRIBUTED CHECKS PASSED")
