"""Batch decode fast path: `read_range`/`read_many`/`read_batch` must be
observationally identical to a scalar `value_at` loop — same values AND the
same `ReadCounters` (cells_decoded, bytes_decoded, bytes_touched, ...) — for
every column kind (plain/skiplist/cblock/dcsl) and every cell type, so the
paper's Table-1 accounting holds on the vectorized path.  Randomized with
fixed seeds (hypothesis is an optional dep; these run everywhere)."""
import random

import numpy as np
import pytest

from repro.core import ARRAY, BOOL, BYTES, FLOAT32, FLOAT64, INT32, INT64, MAP, STRING
from repro.core.cif import CIFReader
from repro.core.colfile import ColumnFileReader, ColumnFileWriter, ColumnFormat
from repro.core.cof import COFWriter
from repro.core.schema import Schema, urlinfo_schema
from repro.core.varcodec import (
    decode_range,
    decode_ragged_range,
    decode_varint_range,
    encode_cell,
    skip_range,
)
from repro.data.tokens import TokenCorpus, TokenCorpusWriter
from repro.data.pipeline import HostPipeline
from conftest import make_crawl_records

N = 2600  # spans multiple skip groups, dict blocks, and cblocks

KINDS = [
    ColumnFormat("plain"),
    ColumnFormat("skiplist"),
    ColumnFormat("cblock", codec="lzo"),
    ColumnFormat("cblock", codec="zlib"),
]


def _values(typ, rnd, n=N):
    k = typ.kind
    if k == "int32":
        return [rnd.randint(-(2**31), 2**31 - 1) for _ in range(n)]
    if k == "int64":
        return [rnd.randint(-(2**63), 2**63 - 1) for _ in range(n)]
    if k == "float32":
        return [float(np.float32(rnd.uniform(-1e6, 1e6))) for _ in range(n)]
    if k == "float64":
        return [rnd.uniform(-1e12, 1e12) for _ in range(n)]
    if k == "bool":
        return [rnd.random() < 0.5 for _ in range(n)]
    if k == "string":
        return ["x" * rnd.randint(0, 200) + str(i) for i in range(n)]
    if k == "bytes":
        return [bytes([i % 251]) * rnd.randint(0, 64) for i in range(n)]
    if k == "map":
        return [
            {f"k{rnd.randint(0, 15)}": rnd.randint(-99, 99) for _ in range(rnd.randint(0, 6))}
            for _ in range(n)
        ]
    if k == "array":
        return [
            [_values(typ.elem, rnd, 1)[0] for _ in range(rnd.randint(0, 5))]
            for _ in range(n)
        ]
    raise AssertionError(k)


def _build(typ, fmt, vals):
    w = ColumnFileWriter(typ, fmt)
    for v in vals:
        w.append(v)
    return w.finish()


def _as_list(v):
    return v.tolist() if isinstance(v, np.ndarray) else v


CELL_TYPES = [
    INT32(), INT64(), FLOAT32(), FLOAT64(), BOOL(), STRING(), BYTES(),
    MAP(INT32()), ARRAY(STRING()),
]


@pytest.mark.parametrize("fmt", KINDS, ids=lambda f: f"{f.kind}-{f.codec}")
@pytest.mark.parametrize("typ", CELL_TYPES, ids=lambda t: t.kind)
def test_read_range_matches_value_at(fmt, typ, rnd):
    vals = _values(typ, rnd)
    raw = _build(typ, ColumnFormat(fmt.kind, codec=fmt.codec), vals)
    scalar = ColumnFileReader(raw, typ)
    batch = ColumnFileReader(raw, typ)
    expect = [scalar.value_at(i) for i in range(len(vals))]
    got = _as_list(batch.read_range(0, len(vals)))
    assert got == expect == vals
    assert vars(batch.counters) == vars(scalar.counters)


@pytest.mark.parametrize("fmt", KINDS, ids=lambda f: f"{f.kind}-{f.codec}")
@pytest.mark.parametrize("typ", [INT64(), STRING(), FLOAT32()], ids=lambda t: t.kind)
def test_read_many_matches_sparse_value_at(fmt, typ, rnd):
    """Gappy monotone access: identical values and identical counters,
    including skip accounting (cells_skipped / bytes_touched)."""
    vals = _values(typ, rnd)
    raw = _build(typ, ColumnFormat(fmt.kind, codec=fmt.codec), vals)
    idx = sorted(rnd.sample(range(len(vals)), 211))
    scalar = ColumnFileReader(raw, typ)
    batch = ColumnFileReader(raw, typ)
    expect = [scalar.value_at(i) for i in idx]
    got = _as_list(batch.read_many(idx))
    assert got == expect
    assert vars(batch.counters) == vars(scalar.counters)


def test_dcsl_read_range_matches_value_at(rnd):
    typ = MAP(STRING())
    vals = [
        {f"key{rnd.randint(0, 15)}": f"v{rnd.randint(0, 99)}" for _ in range(5)}
        for _ in range(N)
    ]
    raw = _build(typ, ColumnFormat("dcsl"), vals)
    scalar = ColumnFileReader(raw, typ)
    batch = ColumnFileReader(raw, typ)
    expect = [scalar.value_at(i) for i in range(len(vals))]
    assert batch.read_range(0, len(vals)) == expect == vals
    assert vars(batch.counters) == vars(scalar.counters)
    # sparse across dictionary blocks
    idx = sorted(rnd.sample(range(len(vals)), 97))
    s2, b2 = ColumnFileReader(raw, typ), ColumnFileReader(raw, typ)
    assert b2.read_many(idx) == [s2.value_at(i) for i in idx]
    assert vars(b2.counters) == vars(s2.counters)


def test_read_range_chunked_equals_whole(rnd):
    """Monotone chunked reads compose: sum of ranges == one range."""
    vals = _values(INT64(), rnd)
    for fmt in KINDS:
        raw = _build(INT64(), ColumnFormat(fmt.kind, codec=fmt.codec), vals)
        whole = ColumnFileReader(raw, INT64()).read_range(0, len(vals))
        r = ColumnFileReader(raw, INT64())
        parts = []
        start = 0
        while start < len(vals):
            stop = min(len(vals), start + rnd.randint(1, 400))
            parts.append(r.read_range(start, stop))
            start = stop
        np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_read_range_empty_and_bounds(rnd):
    vals = _values(INT32(), rnd, 50)
    raw = _build(INT32(), ColumnFormat("plain"), vals)
    r = ColumnFileReader(raw, INT32())
    assert len(r.read_range(5, 5)) == 0
    assert len(r.read_many([])) == 0
    assert r.counters.cells_decoded == 0
    assert r.read_range(10, 12).tolist() == vals[10:12]
    with pytest.raises(AssertionError):
        r.read_range(0, 5)  # monotone: reader already past 0


def test_varcodec_range_decoders_roundtrip(rnd):
    ints = [rnd.randint(-(2**63), 2**63 - 1) for _ in range(1000)]
    ints += [0, 1, -1, 2**63 - 1, -(2**63)]
    buf = bytearray()
    for v in ints:
        encode_cell(INT64(), v, buf)
    got, end = decode_varint_range(bytes(buf), 0, len(ints))
    assert got.tolist() == ints and end == len(buf)
    assert skip_range(INT64(), bytes(buf), 0, len(ints)) == len(buf)
    # ragged: offsets index the raw buffer payloads exactly
    blobs = [bytes([65 + i % 26]) * (i % 300) for i in range(400)]
    buf = bytearray()
    for v in blobs:
        encode_cell(BYTES(), v, buf)
    starts, lengths, end = decode_ragged_range(bytes(buf), 0, len(blobs))
    assert end == len(buf)
    data = bytes(buf)
    assert [data[s : s + l] for s, l in zip(starts.tolist(), lengths.tolist())] == blobs
    vals, end2 = decode_range(BYTES(), data, 0, len(blobs))
    assert vals == blobs and end2 == end


# -- split/CIF layer ---------------------------------------------------------


def test_split_read_batch_matches_scan(tmp_path):
    records = make_crawl_records(300)
    root = str(tmp_path / "d")
    w = COFWriter(
        root, urlinfo_schema(),
        formats={"metadata": ColumnFormat("dcsl"), "fetchTime": ColumnFormat("skiplist"),
                 "content": ColumnFormat("cblock", codec="zlib")},
        split_records=128,
    )
    w.append_all(records)
    w.close()
    cols = ["url", "fetchTime", "metadata", "content"]
    r = CIFReader(root, columns=cols)
    rows = []
    for batch in r.scan_batches(batch_size=50):
        vals = {n: _as_list(batch[n]) for n in cols}
        k = len(vals[cols[0]])
        rows.extend({n: vals[n][i] for n in cols} for i in range(k))
    assert rows == [{n: rec[n] for n in cols} for rec in records]
    # ScanStats parity with a record-at-a-time eager scan
    r2 = CIFReader(root, columns=cols, lazy=False)
    list(r2.scan())
    assert vars(r.stats) == vars(r2.stats)


def test_split_read_batch_sparse(tmp_path, rnd):
    records = make_crawl_records(200)
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=200)
    w.append_all(records)
    w.close()
    r = CIFReader(root, columns=["url", "fetchTime"])
    sr = r.open_split(r.splits()[0][1])
    idx = sorted(rnd.sample(range(200), 40))
    batch = sr.read_batch(idx)
    assert _as_list(batch["url"]) == [records[i]["url"] for i in idx]
    assert _as_list(batch["fetchTime"]) == [records[i]["fetchTime"] for i in idx]


# -- token / pipeline layer ---------------------------------------------------


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    root = tmp_path_factory.mktemp("corpus-batch")
    w = TokenCorpusWriter(str(root), seq_len=64, split_records=32)
    from repro.launch.load_data import synth_token_docs

    for toks, meta in synth_token_docs(150, vocab=300):
        w.add_document(toks, meta)
    w.close()
    return TokenCorpus(str(root))


@pytest.mark.parametrize("decode", ["np", "py", "packed"])
def test_token_record_batch_matches_scalar(corpus, decode, rnd):
    sid = corpus.split_ids()[0]
    sp_b, sp_s = corpus.open_split(sid), corpus.open_split(sid)
    ids = sorted(rnd.sample(range(len(sp_b)), 12))
    tb, mb = sp_b.record_batch(ids, decode=decode)
    scalars = [sp_s.record(i, decode=decode) for i in ids]
    np.testing.assert_array_equal(tb, np.stack([t for t, _ in scalars]))
    np.testing.assert_array_equal(mb, np.stack([m for _, m in scalars]))
    # identical decode work reported by the column readers
    cb = {n: vars(r.counters) for n, r in sp_b.reader.readers.items()}
    cs = {n: vars(r.counters) for n, r in sp_s.reader.readers.items()}
    assert cb == cs


def test_token_record_batch_device_matches_np(corpus, rnd):
    sid = corpus.split_ids()[0]
    sp_d, sp_n = corpus.open_split(sid), corpus.open_split(sid)
    ids = sorted(rnd.sample(range(len(sp_d)), 8))
    td, md = sp_d.record_batch(ids, decode="device")
    tn, mn = sp_n.record_batch(ids, decode="np")
    np.testing.assert_array_equal(td, tn)
    np.testing.assert_array_equal(md, mn)


def test_pipeline_device_decode_matches_np(corpus):
    p_np = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=5, decode="np")
    p_dev = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=5, decode="device")
    it_np, it_dev = iter(p_np), iter(p_dev)
    for _ in range(3):
        a, b = next(it_np), next(it_dev)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
        np.testing.assert_array_equal(a["loss_mask"], b["loss_mask"])


def test_pipeline_shared_block_cache_reuse(corpus):
    # the old ad-hoc open-split map is gone: decoded-block reuse rides the
    # shared BlockCache, stays within its byte budget, and is metered
    pipe = HostPipeline(corpus, batch_per_host=4, prefetch=0, seed=1)
    it = iter(pipe)
    for _ in range(12):
        next(it)
        assert pipe.cache.current_bytes <= pipe.cache.capacity_bytes
    assert pipe.cache.hits > 0  # revisited splits reuse decoded blocks
    assert pipe.stats.cache_hits == pipe.cache.hits
    assert pipe.stats.bytes_served_from_cache == pipe.cache.bytes_served
