"""Per-architecture smoke tests (reduced configs, assignment requirement)
plus decode-vs-forward consistency for the cache machinery."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_configs, get_config, reduced
from repro.models import lm
from repro.models.spec import init_params
from repro.models.ssm import chunked_linear_recurrence, linear_recurrence_step

ARCHS = sorted(all_configs())


def _batch(cfg, B=2, S=64, rng=None):
    rng = rng or np.random.default_rng(0)
    if cfg.frontend == "audio":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
    if cfg.frontend == "vision":
        st = S - cfg.n_patches
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32),
            "patches": jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32),
            "loss_mask": jnp.ones((B, st), jnp.float32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward/train step on CPU, shapes + no NaNs."""
    cfg = reduced(get_config(arch))
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def step(p, b):
        loss, metrics = lm.loss_fn(p, b, cfg)
        return loss, metrics

    (loss, metrics), grads = jax.jit(jax.value_and_grad(step, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["tokens"]) > 0
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_shape_applicability(arch):
    cfg = get_config(arch)
    shapes = cfg.applicable_shapes()
    assert "train_4k" in shapes and "prefill_32k" in shapes
    if not cfg.supports_decode:
        assert "decode_32k" not in shapes
        assert cfg.skip_reason("decode_32k")
    if not cfg.subquadratic and cfg.supports_decode:
        assert cfg.skip_reason("long_500k")
    # exact assigned configs spot-check
    full = get_config(arch)
    assert full.n_layers >= 16 and full.vocab_size >= 504


@pytest.mark.parametrize(
    "arch", ["tinyllama-1.1b", "gemma3-12b", "zamba2-1.2b", "xlstm-350m", "olmoe-1b-7b"]
)
def test_decode_matches_forward(arch):
    """Prefill + step-by-step decode must reproduce full-forward logits —
    validates KV caches, rolling windows, and recurrent state threading."""
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32", remat="none")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    B, S, T = 2, 48, 6  # prompt 48, decode 6 more
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S + T)), jnp.int32)

    # reference: full forward logits at each position (teacher forcing)
    def full_logits(p, t):
        x = lm.embed_inputs(p, {"tokens": t}, cfg, jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(t.shape[1], dtype=jnp.int32), t.shape)
        x, _, _ = lm._run_segments(p, x, cfg, pos)
        from repro.models import layers as L

        x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
        return L.logits_fn(p, x, cfg)

    ref = jax.jit(full_logits)(params, toks)  # (B, S+T, V)

    logits0, caches = jax.jit(
        lambda p, b: lm.prefill(p, b, cfg, cache_len=S + T)
    )(params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(
        np.asarray(logits0[:, -1]), np.asarray(ref[:, S - 1]), rtol=5e-3, atol=5e-3
    )
    step = jax.jit(lambda p, c, t, q: lm.decode_step(p, c, t, q, cfg))
    for i in range(T):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, caches = step(params, caches, toks[:, S + i : S + i + 1], pos)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref[:, S + i]),
            rtol=5e-3, atol=5e-3,
        )


def test_chunked_recurrence_vs_naive(rng):
    B, S, H, N, P = 2, 64, 3, 5, 7
    q = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    log_g = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.5, jnp.float32)
    a = jnp.asarray(np.abs(rng.normal(size=(B, S, H))), jnp.float32)
    for normalize in (False, True):
        outs = []
        for chunk in (8, 16, 64):
            y, (Sf, nf) = chunked_linear_recurrence(
                q, k, v, log_g, a, normalize=normalize, chunk=chunk
            )
            outs.append(np.asarray(y))
        np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
        np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)
        # continuation equivalence: chunked prefix + stepwise == full
        y1, st = chunked_linear_recurrence(
            q[:, :32], k[:, :32], v[:, :32], log_g[:, :32], a[:, :32],
            normalize=normalize, chunk=16,
        )
        ys = []
        for t in range(32, 40):
            yt, st = linear_recurrence_step(
                q[:, t], k[:, t], v[:, t], log_g[:, t], a[:, t], st,
                normalize=normalize,
            )
            ys.append(np.asarray(yt))
        np.testing.assert_allclose(
            np.stack(ys, 1), outs[0][:, 32:40], atol=1e-4
        )


def test_loss_chunking_equivalent():
    cfg = dataclasses.replace(reduced(get_config("stablelm-1.6b")), dtype="float32")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg, B=2, S=64)
    l0, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg, loss_chunk=0))(params, batch)
    l1, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg, loss_chunk=16))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_q_chunking_equivalent():
    cfg = dataclasses.replace(reduced(get_config("tinyllama-1.1b")), dtype="float32")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg, B=2, S=64)
    l0, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg, q_chunk=0))(params, batch)
    l1, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg, q_chunk=16))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_moe_capacity_equals_ragged_when_no_drops():
    from repro.models import moe as M
    from repro.models.spec import init_params as ip

    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")), dtype="float32")
    p = ip(M.moe_spec(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y0, _ = M.moe_apply_ragged(p, x, cfg)
    y1, _ = M.moe_apply_capacity(p, x, cfg, capacity_factor=float(cfg.n_experts))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_moe_capacity_drops_bounded():
    """With cf=1.25 the dropped fraction must be small for balanced routing
    and the output finite regardless."""
    from repro.models import moe as M
    from repro.models.spec import init_params as ip

    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")), dtype="float32")
    p = ip(M.moe_spec(cfg), jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 64, cfg.d_model)), jnp.float32)
    y, aux = M.moe_apply_capacity(p, x, cfg, capacity_factor=1.25)
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma3-12b", "olmoe-1b-7b"])
def test_grouped_kv_equals_gather(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    params = init_params(lm.param_spec(cfg), jax.random.PRNGKey(1))
    batch = _batch(cfg, B=2, S=32)
    l0, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg))(params, batch)
    cfg_g = dataclasses.replace(cfg, attn_kv_mode="grouped")
    l1, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg_g))(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4
    # decode path too
    caches = lm.init_cache(cfg, 1, 16)
    t = jnp.asarray([[3]], jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    lg0, _ = lm.decode_step(params, caches, t, pos, cfg)
    lg1, _ = lm.decode_step(params, lm.init_cache(cfg_g, 1, 16), t, pos, cfg_g)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), atol=1e-3)


def test_param_counts_sane():
    # full configs should land near their nameplate sizes
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "stablelm-1.6b": (1.3e9, 2.0e9),
        "olmoe-1b-7b": (6.0e9, 8.0e9),
        "dbrx-132b": (110e9, 150e9),
        # 9.8B: the assigned numbers give head_dim 3840/16=240 (vs. 256 in
        # the HF release), so slightly under nameplate
        "gemma3-12b": (9.0e9, 14e9),
        # 4.65B: includes the 24->32 q-head TP padding (see configs file)
        "phi4-mini-3.8b": (3.3e9, 5.0e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = lm.n_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active < total
    assert lm.n_active_params(get_config("olmoe-1b-7b")) < lm.n_params(get_config("olmoe-1b-7b"))
