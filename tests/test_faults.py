"""Fault-tolerant scan engine (PR 6): block checksums, graceful container
errors, deterministic fault injection, replica failover, split re-execution,
and mid-job host death.

The load-bearing invariant throughout: under any seeded FaultPlan that
leaves every split at least one surviving replica, job OUTPUT, remote_reads,
and the pre-existing ScanStats fields are bit-identical to a no-fault serial
run — and the new failure counters are themselves deterministic."""
import json
import os

import pytest

from repro.core import (
    CIFReader, COFWriter, ColumnFileReader, ColumnFileWriter, ColumnFormat,
    ColumnType, BlockCorruptionError, CorruptFileError, CoverageError,
    FailurePolicy, FaultPlan, Placement, SplitRetryExhausted, WorkQueue,
    read_schema, urlinfo_schema,
)
from repro.core.faults import ATTEMPT_STRIDE
from repro.core.mapreduce import (
    fig1_map_batch, fig1_reduce, fig1_where, run_job,
)
from conftest import make_crawl_records

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")

V32_TYPES = {
    "plain_int64": ColumnType("int64"),
    "cblock_zlib_string": ColumnType("string"),
    "skiplist_string": ColumnType("string"),
    "dcsl_map": ColumnType("map", value=ColumnType("string")),
}

# tests never sleep: backoff is simulated (real_sleep=False is the default)
POLICY = FailurePolicy()


def _fix(name):
    with open(os.path.join(FIXTURES, f"v32_{name}.col"), "rb") as f:
        return f.read()


def _as_list(vals):
    return vals.tolist() if hasattr(vals, "tolist") else list(vals)


# -- v3.2 fixtures in the compat matrix ---------------------------------------


def test_v32_fixtures_read_verify_and_match_expected():
    with open(os.path.join(FIXTURES, "v32_expected.json")) as f:
        exp = json.load(f)
    for name, typ in V32_TYPES.items():
        raw = _fix(name)
        r = ColumnFileReader(raw, typ)
        assert r.version == 3 and r.format_version == "3.2"
        assert r.checksum == "crc32c"
        assert r.verify_checksums() == "crc32c"
        assert _as_list(r.read_range(0, r.n)) == exp[name], name


def test_old_files_report_absent_checksum():
    for fname, typ in [
        ("v3_plain_int64.col", ColumnType("int64")),
        ("v31_cblock_zlib_string.col", ColumnType("string")),
        ("prepr_plain_int64.col", ColumnType("int64")),
    ]:
        with open(os.path.join(FIXTURES, fname), "rb") as f:
            r = ColumnFileReader(f.read(), typ)
        assert r.checksum == "absent"
        assert r.verify_checksums() == "absent"  # audit is a no-op, not a crash


def test_fresh_files_carry_checksums_for_every_kind():
    cases = [
        (ColumnType("int64"), ColumnFormat("plain", enc_block=32),
         list(range(100))),
        (ColumnType("string"), ColumnFormat("cblock", codec="zlib",
                                            block_records=32),
         [f"v{i % 7}" for i in range(100)]),
        (ColumnType("string"), ColumnFormat("skiplist"),
         [f"url/{i}" for i in range(100)]),
        (ColumnType("map", value=ColumnType("string")), ColumnFormat("dcsl"),
         [{"k": str(i % 5)} for i in range(100)]),
    ]
    for typ, fmt, vals in cases:
        w = ColumnFileWriter(typ, fmt)
        for v in vals:
            w.append(v)
        r = ColumnFileReader(w.finish(), typ)
        assert r.format_version == "3.2" and r.checksum == "crc32c"
        assert r.verify_checksums() == "crc32c"
        assert _as_list(r.read_range(0, r.n)) == vals


def test_verification_leaves_read_counters_untouched():
    """Lazy verification must not perturb the PR 1-5 instrumentation:
    counters with verify on == counters with verify off, bit for bit."""
    raw = _fix("cblock_zlib_string")
    typ = V32_TYPES["cblock_zlib_string"]
    r_on = ColumnFileReader(raw, typ, verify=True)
    r_off = ColumnFileReader(raw, typ, verify=False)
    assert _as_list(r_on.read_range(0, r_on.n)) == \
        _as_list(r_off.read_range(0, r_off.n))
    assert vars(r_on.counters) == vars(r_off.counters)


# -- byte-flip property: detect or be bit-identical, never silently wrong ----


@pytest.mark.parametrize("name", sorted(V32_TYPES))
def test_single_byte_flip_never_silently_wrong(name):
    """Flip ONE byte at deterministic offsets across the whole file.  The
    reader must either raise a typed corruption error (at open or on first
    touch) or return bit-identical data (the flip landed on a byte only
    covered by the whole-file CRC, which lazy reads don't consult) — a
    silently different value list is the one forbidden outcome."""
    import random

    raw = _fix(name)
    typ = V32_TYPES[name]
    r0 = ColumnFileReader(raw, typ)
    truth = _as_list(r0.read_range(0, r0.n))
    rnd = random.Random(20260809)
    offsets = sorted(rnd.sample(range(len(raw)), 48))
    detected = 0
    for off in offsets:
        bad = bytearray(raw)
        bad[off] ^= 1 + rnd.randrange(255)
        try:
            r = ColumnFileReader(bytes(bad), typ)
            got = _as_list(r.read_range(0, r.n))
        except (CorruptFileError, OSError) as e:
            assert isinstance(e, (BlockCorruptionError, CorruptFileError))
            detected += 1
            continue
        assert got == truth, f"silent corruption at offset {off}"
    # the grid really bites: most flips in a dense file are detected
    assert detected > len(offsets) // 2, (name, detected)


def test_full_audit_catches_what_lazy_reads_may_not():
    """verify_checksums() walks meta + every block + the whole-file CRC, so
    ANY single-byte flip is detected, including in never-read regions."""
    import random

    raw = _fix("plain_int64")
    rnd = random.Random(7)
    for off in sorted(rnd.sample(range(len(raw)), 32)):
        bad = bytearray(raw)
        bad[off] ^= 0x40
        with pytest.raises(CorruptFileError):
            r = ColumnFileReader(bytes(bad), ColumnType("int64"))
            r.verify_checksums()


# -- graceful container errors (satellite a) ----------------------------------


def test_truncated_column_file_raises_corrupt_file_error():
    raw = _fix("skiplist_string")
    for cut in (0, 3, 10, len(raw) // 2, len(raw) - 5):
        with pytest.raises(CorruptFileError) as ei:
            ColumnFileReader(raw[:cut], ColumnType("string"),
                             path="/data/x.col")
        assert ei.value.path == "/data/x.col"
        assert ei.value.offset >= 0  # names where parsing fell off the end


def test_truncated_meta_and_schema_raise_corrupt_file_error(tmp_path):
    root = str(tmp_path / "d")
    w = COFWriter(root, urlinfo_schema(), split_records=64)
    w.append_all(make_crawl_records(100))
    w.close()
    # truncate schema.json mid-token
    spath = os.path.join(root, "schema.json")
    blob = open(spath, "rb").read()
    with open(spath, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(CorruptFileError) as ei:
        read_schema(root)
    assert "schema.json" in ei.value.path and ei.value.offset >= 0
    with open(spath, "wb") as f:
        f.write(blob)  # restore
    # truncate a split's _meta.json
    split0 = CIFReader(root).splits()[0][1]
    mpath = os.path.join(split0, "_meta.json")
    mblob = open(mpath, "rb").read()
    with open(mpath, "wb") as f:
        f.write(mblob[: len(mblob) // 2])
    with pytest.raises(CorruptFileError) as ei:
        CIFReader(root, columns=["url"]).open_split(split0)
    assert "_meta.json" in ei.value.path


# -- WorkQueue mid-job death (satellite b) ------------------------------------


def test_workqueue_mark_dead_makes_claims_stealable():
    p = Placement(n_splits=4, n_hosts=3, replication=2)
    wq = WorkQueue(p)
    s = wq.next_split(0)
    assert wq.claimed[s] == 0
    wq.mark_dead(0)
    # a replica holder steals the in-flight split; the steal is counted
    thief = next(h for h in p.replicas(s) if h != 0)
    got = set()
    while (n := wq.next_split(thief)) is not None:
        got.add(n)
        wq.complete(n)
    assert s in got and wq.reexecutions == 1


def test_workqueue_mark_dead_raises_when_last_replica_lost():
    p = Placement(n_splits=3, n_hosts=3, replication=1)  # one copy per split
    assert len({p.primary(s) for s in range(3)}) == 3  # round-robin: distinct
    wq = WorkQueue(p)
    wq.complete(0)
    wq.mark_dead(p.primary(0))  # its only split already finished: fine
    assert wq.coverage_possible()
    with pytest.raises(CoverageError):
        wq.mark_dead(p.primary(1))  # split 1 just lost its only copy
    assert not wq.coverage_possible()


def test_workqueue_requeue_bumps_epoch_and_caps():
    p = Placement(n_splits=2, n_hosts=2)
    wq = WorkQueue(p)
    s = wq.next_split(0)
    assert wq.epoch(s) == 0
    assert wq.requeue(s, max_reexecutions=2) and wq.epoch(s) == 1
    assert s in {wq.next_split(0)}  # claimable again
    assert wq.requeue(s, max_reexecutions=2) and wq.epoch(s) == 2
    assert not wq.requeue(s, max_reexecutions=2)  # third strike: caller fails
    assert wq.reexecutions == 3


# -- replica failover keeps jobs bit-identical (tentpole) ---------------------


N_SPLITS, N_HOSTS = 6, 4


@pytest.fixture(scope="module")
def crawl(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("faults-crawl") / "d")
    records = make_crawl_records(600)
    w = COFWriter(root, urlinfo_schema(),
                  formats={"metadata": ColumnFormat("dcsl"),
                           "url": ColumnFormat("skiplist")},
                  split_records=100)
    w.append_all(records)
    w.close()
    return root


def _pre_existing(stats):
    """The PR 1-5 ScanStats fields — the ones faults must not move."""
    return {k: getattr(stats, k) for k in (
        "bytes_io", "bytes_touched", "bytes_decoded", "cells_decoded",
        "cells_skipped", "blocks_decompressed", "records_scanned",
        "files_opened", "blocks_pruned_stats", "rows_short_circuited")}


def _failure_counters(stats):
    return {k: getattr(stats, k) for k in (
        "checksum_failures", "read_retries", "replica_failovers",
        "splits_reexecuted")}


def _run(root, plan=None, policy=None, n_workers=1, dead_hosts=None):
    p = Placement(N_SPLITS, N_HOSTS)
    r = CIFReader(root, columns=["url", "metadata"],
                  fault_plan=plan, failure_policy=policy)
    ids, ob = r.job_inputs(batch_size=64, where=fig1_where(), placement=p)
    res = run_job(ids, reduce_fn=fig1_reduce, n_hosts=N_HOSTS, placement=p,
                  dead_hosts=dead_hosts, open_split_batches=ob,
                  map_batch_fn=fig1_map_batch(), n_workers=n_workers,
                  fault_plan=plan, failure_policy=policy, scan_stats=r.stats)
    return res, r.stats, p


def test_corrupt_replica_fails_over_bit_identically(crawl):
    base, base_stats, p = _run(crawl)
    # damage the PRIMARY replica's copy of two splits plus a persistent IO
    # error on a third — every split keeps >= 1 clean replica
    plan = FaultPlan(
        corrupt_blocks=frozenset({(p.primary(1), 1, "url", 0),
                                  (p.primary(4), 4, "metadata", 0)}),
        io_errors=frozenset({(p.primary(2), 2, "url")}),
    )
    for n_workers in (1, 4):
        res, stats, _ = _run(crawl, plan, POLICY, n_workers=n_workers)
        assert res.output == base.output
        assert res.remote_reads == base.remote_reads == 0
        assert _pre_existing(stats) == _pre_existing(base_stats)
        fc = _failure_counters(stats)
        assert fc["checksum_failures"] >= 2  # both corrupt blocks detected
        assert fc["read_retries"] >= 3 and fc["replica_failovers"] >= 3
        assert fc["splits_reexecuted"] == 0  # in-read failover, no requeue
        assert res.splits_reexecuted == 0 and res.hosts_failed == 0
    # and the counters themselves are deterministic across reruns/schedules
    s1 = _failure_counters(_run(crawl, plan, POLICY, n_workers=1)[1])
    s4 = _failure_counters(_run(crawl, plan, POLICY, n_workers=4)[1])
    assert s1 == s4 == _failure_counters(stats)


def test_rate_based_transient_faults_deterministic(crawl):
    base, base_stats, _ = _run(crawl)
    plan = FaultPlan(seed=3, io_error_rate=0.25, latency_rate=0.5,
                     latency_s=0.005)
    res1, st1, _ = _run(crawl, plan, POLICY, n_workers=1)
    res2, st2, _ = _run(crawl, plan, POLICY, n_workers=4)
    assert res1.output == res2.output == base.output
    assert _pre_existing(st1) == _pre_existing(base_stats)
    assert _failure_counters(st1) == _failure_counters(st2)
    assert st1.read_retries > 0  # the rate actually fired
    assert st1.simulated_delay_s > 0.0  # latency simulated, never slept


def test_retry_exhaustion_requeues_split_with_fresh_epoch(crawl):
    base, base_stats, _ = _run(crawl)
    # every replica of split 2's url column is damaged while attempt <
    # threshold; threshold > max_attempts forces exhaustion + re-enqueue,
    # and the re-execution's attempts (>= ATTEMPT_STRIDE) read clean
    threshold = POLICY.max_attempts + 3
    assert threshold < ATTEMPT_STRIDE
    plan = FaultPlan(corrupt_until={(2, "url"): threshold})
    for n_workers in (1, 4):
        res, stats, _ = _run(crawl, plan, POLICY, n_workers=n_workers)
        assert res.output == base.output
        assert res.splits_reexecuted == 1
        assert stats.splits_reexecuted == 1
        assert _pre_existing(stats) == _pre_existing(base_stats)


def test_unrecoverable_corruption_fails_the_job(crawl):
    # corrupt beyond the re-execution budget: epochs 0..max_reexecutions
    # all read damaged -> the job surfaces the failure instead of looping
    plan = FaultPlan(corrupt_until={
        (0, "url"): (POLICY.max_reexecutions + 1) * ATTEMPT_STRIDE})
    with pytest.raises((SplitRetryExhausted, CorruptFileError)):
        _run(crawl, plan, POLICY)


def test_midjob_host_death_steals_in_flight_split(crawl):
    base, base_stats, p = _run(crawl)
    victim = p.primary(0)
    plan = FaultPlan(fail_at={victim: 1})  # dies holding its first claim
    for n_workers in (1, 4):
        res, stats, _ = _run(crawl, plan, POLICY, n_workers=n_workers)
        assert res.output == base.output
        assert res.hosts_failed == 1
        assert res.splits_reexecuted == 1  # the stolen in-flight split
        assert res.remote_reads == 0  # thief held a replica (CPP invariant)
        assert victim not in set(res.host_of_split.values())
        assert _pre_existing(stats) == _pre_existing(base_stats)


def test_start_dead_hosts_via_fail_at_zero(crawl):
    base, _, p = _run(crawl)
    plan = FaultPlan(fail_at={p.primary(3): 0})  # k <= 0: dead at start
    res, _, _ = _run(crawl, plan, POLICY)
    assert res.output == base.output
    assert res.hosts_failed == 0  # start-time deaths aren't MID-job failures
    assert res.splits_reexecuted == 0  # never claimed, so never re-executed


def test_death_plus_corruption_compose(crawl):
    base, base_stats, p = _run(crawl)
    victim = p.primary(5)
    plan = FaultPlan(
        fail_at={victim: 1},
        corrupt_blocks=frozenset({(p.primary(1), 1, "url", 0)}),
    )
    outs, counters = [], []
    for n_workers in (1, 4):
        res, stats, _ = _run(crawl, plan, POLICY, n_workers=n_workers)
        outs.append(res.output)
        counters.append(_failure_counters(stats))
        assert res.hosts_failed == 1 and res.splits_reexecuted == 1
        assert _pre_existing(stats) == _pre_existing(base_stats)
    assert outs[0] == outs[1] == base.output
    assert counters[0] == counters[1]


# -- serving-path recovery (PromptStore) --------------------------------------


@pytest.fixture(scope="module")
def token_root(tmp_path_factory):
    from repro.data.tokens import TokenCorpusWriter
    from repro.launch.load_data import synth_token_docs

    root = str(tmp_path_factory.mktemp("faults-corpus"))
    w = TokenCorpusWriter(root, seq_len=32, split_records=16)
    for toks, meta in synth_token_docs(40, vocab=120, seed=3):
        w.add_document(toks % 50 + 1, meta)
    w.close()
    return root


def test_prompt_store_reexecutes_through_corruption(token_root):
    from repro.data.tokens import TokenCorpus
    from repro.serving.engine import PromptStore

    clean = PromptStore(TokenCorpus(token_root), max_prompt=5)
    refs = [(0, 3), (1, 7), (0, 9), (1, 2)]
    truth = clean.fetch(refs)

    threshold = POLICY.max_attempts + 2  # exhaust epoch 0, clean at epoch 1
    plan = FaultPlan(corrupt_until={(0, "tokens"): threshold})
    corpus = TokenCorpus(token_root, fault_plan=plan, failure_policy=POLICY)
    store = PromptStore(corpus, max_prompt=5, policy=POLICY)
    assert store.fetch(refs) == truth

    # without a policy the store has no re-execution budget: it surfaces
    strict = PromptStore(
        TokenCorpus(token_root, fault_plan=plan, failure_policy=POLICY),
        max_prompt=5)
    with pytest.raises((SplitRetryExhausted, CorruptFileError)):
        strict.fetch(refs)
